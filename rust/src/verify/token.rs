//! Paper Algorithm 1 — standard token-by-token verification
//! (Leviathan et al. 2022), the baseline the paper improves on.

use super::dist::{pos_diff_into, residual_pick, ProbMatrix, EPS};
use super::VerifyOutcome;

/// Verify a draft block token-by-token.
///
/// * `ps`: `(gamma+1, V)` — `ps[i] = M_b(. | c, X^i)`, `ps[0] = M_b(. | c)`.
/// * `qs`: `(gamma,   V)` — `qs[i] = M_s(. | c, X^i)`.
/// * `drafts`: `X_1..X_gamma`.
/// * `etas`, `u_final`: explicit uniforms (draw-for-draw testability).
///
/// Accepts `X_i` with prob `min(1, p/q)` (Eq. 1), stops at the first
/// rejection, then samples the bonus/correction token from `M_b` or the
/// residual `norm(max(p - q, 0))` (Eq. 2).
pub fn token_verify(
    ps: &ProbMatrix,
    qs: &ProbMatrix,
    drafts: &[u32],
    etas: &[f64],
    u_final: f64,
) -> VerifyOutcome {
    let gamma = drafts.len();
    debug_assert_eq!(ps.rows, gamma + 1);
    debug_assert_eq!(qs.rows, gamma);
    let mut tau = 0;
    for i in 0..gamma {
        let x = drafts[i] as usize;
        let ratio = ps.row(i)[x] / qs.row(i)[x].max(EPS);
        if etas[i] <= ratio.min(1.0) {
            tau = i + 1;
        } else {
            break;
        }
    }
    let y = if tau == gamma {
        residual_pick(ps.row(gamma), ps.row(gamma), u_final)
    } else {
        let mut res = vec![0.0; ps.vocab];
        pos_diff_into(ps.row(tau), qs.row(tau), &mut res);
        residual_pick(&res, ps.row(tau), u_final)
    };
    let mut emitted: Vec<u32> = drafts[..tau].to_vec();
    emitted.push(y as u32);
    VerifyOutcome { tau, emitted }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: Vec<Vec<f64>>) -> ProbMatrix {
        ProbMatrix::from_rows(rows)
    }

    #[test]
    fn accepts_all_when_models_equal() {
        let ps = mat(vec![vec![0.5, 0.5]; 3]);
        let qs = mat(vec![vec![0.5, 0.5]; 2]);
        let out = token_verify(&ps, &qs, &[0, 1], &[0.99, 0.99], 0.3);
        assert_eq!(out.tau, 2);
        assert_eq!(out.emitted.len(), 3);
    }

    #[test]
    fn rejects_on_high_eta_low_ratio() {
        // ratio for token 0 is 0.2/0.8 = 0.25; eta 0.5 rejects.
        let ps = mat(vec![vec![0.2, 0.8]; 2]);
        let qs = mat(vec![vec![0.8, 0.2]]);
        let out = token_verify(&ps, &qs, &[0], &[0.5], 0.0);
        assert_eq!(out.tau, 0);
        // residual = max(p - q, 0) = [0, 0.6] -> token 1.
        assert_eq!(out.emitted, vec![1]);
    }

    #[test]
    fn stops_at_first_rejection() {
        let ps = mat(vec![vec![0.5, 0.5], vec![0.0, 1.0], vec![0.5, 0.5], vec![0.5, 0.5]]);
        let qs = mat(vec![vec![0.5, 0.5], vec![1.0, 0.0], vec![0.5, 0.5]]);
        // token 2 (draft 0) has ratio 0 -> rejected for any eta > 0.
        let out = token_verify(&ps, &qs, &[0, 0, 0], &[0.3, 0.3, 0.3], 0.1);
        assert_eq!(out.tau, 1);
        assert_eq!(out.emitted[0], 0);
        assert_eq!(out.emitted[1], 1); // residual forced to token 1
    }
}
