//! Joint block verification across `K` candidate draft paths
//! (DESIGN.md §9) — the multi-draft extension of Algorithm 2 in the
//! spirit of SpecTr-GBV / greedy multi-path block verification
//! (PAPERS.md).
//!
//! All `K` paths are drafted i.i.d. from the drafter chain out of the
//! *same* context, so every path's position-0 rows (`ps[k].row(0)`,
//! `qs[k].row(0)`) coincide.  The joint rule is **sequential
//! residual-chained block verification**: maintain a "remaining"
//! position-0 target `D` (initially `M_b(.|c)`), and for each stage `k`
//! run ordinary block verification of path `k` with `D` substituted for
//! the position-0 target row.
//!
//! * If the stage accepts a non-empty prefix (`tau >= 1`), it wins
//!   greedily: its accepted prefix plus the Eq. 3 residual correction is
//!   emitted and the remaining paths are discarded.
//! * If the stage rejects everything (`tau = 0`), the single-path
//!   algorithm would emit one token from the Eq. 3 residual at position
//!   0, `norm(max(D - M_s(.|c), 0))`.  Instead of emitting, that
//!   residual *becomes* the next stage's `D`: path `k + 1` gets a chance
//!   to place a whole accepted prefix where a lone correction token
//!   would have gone.  The last stage emits its correction as usual.
//!
//! Losslessness (proof sketch, DESIGN.md §9.3): each stage is exactly
//! single-path block verification for the modified target process "first
//! token ~ `D`, then `M_b` conditionals", which Theorem 1 makes a valid
//! sampler of that process; delegating the `tau = 0` correction draw to
//! the next stage replaces "sample `y ~ D'`" by "emit a valid sample of
//! the process starting from `D'`" — the same marginal for the first
//! emitted token, with any further tokens distributed as the target
//! conditionals.  By induction over stages the emitted block composes
//! with the outer decode loop into exact target ancestral sampling.  At
//! `K = 1` the loop body is literally [`block_verify`], so
//! `Algo::MultiPath { k: 1 }` is bit-identical to `Algo::Block`
//! (test-enforced).

use super::block::{block_verify, block_verify_row0};
use super::dist::{normalize, ProbMatrix};
use super::VerifyOutcome;

/// Result of jointly verifying a `K`-path draft set for one sequence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MultipathOutcome {
    /// Accepted draft tokens of the winning path.
    pub tau: usize,
    /// Index of the winning path within the draft set (the stage that
    /// emitted).
    pub path: usize,
    /// Accepted prefix of the winning path plus the bonus/correction
    /// token; `emitted.len() == tau + 1` always.
    pub emitted: Vec<u32>,
}

impl MultipathOutcome {
    /// Drop the path index, keeping the single-sequence outcome shape.
    pub fn into_outcome(self) -> VerifyOutcome {
        VerifyOutcome { tau: self.tau, emitted: self.emitted }
    }
}

/// Jointly verify `K` candidate draft paths (one entry per path in every
/// slice; `ps[k]` is `(gamma + 1, V)`, `qs[k]` is `(gamma, V)`,
/// `etas[k]` carries path `k`'s `gamma` acceptance uniforms).  `u_final`
/// is the residual-sampling uniform — only the winning stage consumes
/// it, so a single draw suffices for any `K`.
pub fn multipath_verify(
    ps: &[ProbMatrix],
    qs: &[ProbMatrix],
    drafts: &[Vec<u32>],
    etas: &[Vec<f64>],
    u_final: f64,
) -> MultipathOutcome {
    let k = drafts.len();
    assert!(k >= 1, "multipath needs at least one path");
    assert!(
        ps.len() == k && qs.len() == k && etas.len() == k,
        "ragged multipath set: {} ps, {} qs, {} drafts, {} etas",
        ps.len(),
        qs.len(),
        k,
        etas.len()
    );
    let gamma = drafts[0].len();
    assert!(gamma >= 1, "multipath needs gamma >= 1");

    // Remaining position-0 target: starts at M_b(.|c) (row 0 is the same
    // on every path — the paths share the context) and loses one drafter
    // row of mass per fully-rejected stage.  Allocated lazily: the
    // common stage-0-wins case never touches it.
    let mut d: Vec<f64> = Vec::new();
    for stage in 0..k {
        debug_assert_eq!(drafts[stage].len(), gamma, "ragged path lengths");
        debug_assert_eq!(ps[stage].rows, gamma + 1);
        debug_assert_eq!(qs[stage].rows, gamma);
        // One stage = single-path block verification with the remaining
        // target substituted at position 0 (stage 0 substitutes D = row 0
        // itself, so it calls straight through — the k = 1 degradation).
        // The row-0 override variant substitutes without cloning the
        // `(gamma + 1, V)` target matrix.
        let out = if stage == 0 {
            block_verify(&ps[0], &qs[0], &drafts[0], &etas[0], u_final)
        } else {
            block_verify_row0(
                &ps[stage],
                Some(&d),
                &qs[stage],
                &drafts[stage],
                &etas[stage],
                u_final,
            )
        };
        if out.tau >= 1 || stage == k - 1 {
            return MultipathOutcome { tau: out.tau, path: stage, emitted: out.emitted };
        }
        // tau = 0 with paths to spare: fold this stage's position-0
        // drafter row out of the remaining target (Eq. 3 residual at
        // tau = 0) and hand the correction draw to the next path.
        if stage == 0 {
            d = ps[0].row(0).to_vec();
        }
        for (dv, qv) in d.iter_mut().zip(qs[stage].row(0)) {
            *dv = (*dv - qv).max(0.0);
        }
        if !normalize(&mut d) {
            // Degenerate: the remaining target equals the drafter row (up
            // to float dust), so this stage's correction already fell
            // back to sampling D itself — emit it.
            return MultipathOutcome { tau: 0, path: stage, emitted: out.emitted };
        }
    }
    unreachable!("the last stage always returns");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, rand_instance};
    use crate::verify::Rng;

    #[test]
    fn k1_is_block_verification_bit_for_bit() {
        check("multipath k=1 == block", 200, |rng| {
            let gamma = 1 + rng.below(6);
            let vocab = 2 + rng.below(12);
            let (ps, qs, drafts) = rand_instance(rng, gamma, vocab, 0.8);
            let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
            let u = rng.uniform();
            let want = block_verify(&ps, &qs, &drafts, &etas, u);
            let got = multipath_verify(
                std::slice::from_ref(&ps),
                std::slice::from_ref(&qs),
                std::slice::from_ref(&drafts),
                std::slice::from_ref(&etas),
                u,
            );
            if got.path != 0 || got.tau != want.tau || got.emitted != want.emitted {
                return Err(format!("{got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn later_path_wins_when_first_rejects() {
        // Path 0 drafts token 0, which the target gives zero mass: the
        // chain dies (p_1 = 0, h = 0) and stage 0 rejects everything.
        // Path 1 drafts token 1 with target mass ~1: always accepted.
        let ps0 = ProbMatrix::from_rows(vec![vec![0.0, 1.0]; 2]);
        let qs0 = ProbMatrix::from_rows(vec![vec![0.9, 0.1]]);
        let ps1 = ProbMatrix::from_rows(vec![vec![0.0, 1.0], vec![0.5, 0.5]]);
        let qs1 = ProbMatrix::from_rows(vec![vec![0.9, 0.1]]);
        let out = multipath_verify(
            &[ps0, ps1],
            &[qs0, qs1],
            &[vec![0], vec![1]],
            &[vec![0.5], vec![0.5]],
            0.3,
        );
        assert_eq!(out.path, 1);
        assert_eq!(out.tau, 1);
        assert_eq!(out.emitted[0], 1);
        assert_eq!(out.emitted.len(), 2);
    }

    #[test]
    fn output_invariants_hold_for_any_k() {
        check("multipath invariants", 200, |rng| {
            let gamma = 1 + rng.below(5);
            let vocab = 2 + rng.below(10);
            let k = 1 + rng.below(4);
            let mut ps = Vec::new();
            let mut qs = Vec::new();
            let mut drafts = Vec::new();
            let mut etas: Vec<Vec<f64>> = Vec::new();
            // Same position-0 rows across paths (the shared-context
            // contract): reuse path 0's rows there.
            for path in 0..k {
                let (mut p, mut q, d) = rand_instance(rng, gamma, vocab, 0.8);
                if path > 0 {
                    p.row_mut(0).copy_from_slice(ps[0].row(0));
                    q.row_mut(0).copy_from_slice(qs[0].row(0));
                }
                ps.push(p);
                qs.push(q);
                drafts.push(d);
                etas.push((0..gamma).map(|_| rng.uniform()).collect());
            }
            let out = multipath_verify(&ps, &qs, &drafts, &etas, rng.uniform());
            if out.path >= k {
                return Err(format!("path {} out of range", out.path));
            }
            if out.emitted.len() != out.tau + 1 {
                return Err(format!("len {} tau {}", out.emitted.len(), out.tau));
            }
            if out.emitted[..out.tau] != drafts[out.path][..out.tau] {
                return Err("accepted prefix differs from the winning path".into());
            }
            if out.emitted.iter().any(|&t| t as usize >= vocab) {
                return Err("token out of vocab".into());
            }
            Ok(())
        });
    }

    #[test]
    fn identical_models_accept_path_zero_fully() {
        // ps == qs everywhere: the chain stays at 1, stage 0 accepts the
        // whole block for any etas < 1.
        let row = vec![0.25; 4];
        let ps = ProbMatrix::from_rows(vec![row.clone(); 3]);
        let qs = ProbMatrix::from_rows(vec![row; 2]);
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let etas = vec![rng.uniform(), rng.uniform()];
            let out = multipath_verify(
                &[ps.clone(), ps.clone()],
                &[qs.clone(), qs.clone()],
                &[vec![1, 2], vec![3, 0]],
                &[etas.clone(), etas],
                rng.uniform(),
            );
            assert_eq!(out.path, 0);
            assert_eq!(out.tau, 2);
            assert_eq!(&out.emitted[..2], &[1, 2]);
        }
    }
}
