//! Joint block verification across `K` candidate draft paths
//! (DESIGN.md §9) — the multi-draft extension of Algorithm 2 in the
//! spirit of SpecTr-GBV / greedy multi-path block verification
//! (PAPERS.md).
//!
//! All `K` paths are drafted i.i.d. from the drafter chain out of the
//! *same* context, so every path's position-0 rows (`ps[k].row(0)`,
//! `qs[k].row(0)`) coincide.  The joint rule is **sequential
//! residual-chained block verification**: maintain a "remaining"
//! position-0 target `D` (initially `M_b(.|c)`), and for each stage `k`
//! run ordinary block verification of path `k` with `D` substituted for
//! the position-0 target row.
//!
//! * If the stage accepts a non-empty prefix (`tau >= 1`), it wins
//!   greedily: its accepted prefix plus the Eq. 3 residual correction is
//!   emitted and the remaining paths are discarded.
//! * If the stage rejects everything (`tau = 0`), the single-path
//!   algorithm would emit one token from the Eq. 3 residual at position
//!   0, `norm(max(D - M_s(.|c), 0))`.  Instead of emitting, that
//!   residual *becomes* the next stage's `D`: path `k + 1` gets a chance
//!   to place a whole accepted prefix where a lone correction token
//!   would have gone.  The last stage emits its correction as usual.
//!
//! Losslessness (proof sketch, DESIGN.md §9.3): each stage is exactly
//! single-path block verification for the modified target process "first
//! token ~ `D`, then `M_b` conditionals", which Theorem 1 makes a valid
//! sampler of that process; delegating the `tau = 0` correction draw to
//! the next stage replaces "sample `y ~ D'`" by "emit a valid sample of
//! the process starting from `D'`" — the same marginal for the first
//! emitted token, with any further tokens distributed as the target
//! conditionals.  By induction over stages the emitted block composes
//! with the outer decode loop into exact target ancestral sampling.  At
//! `K = 1` the loop body is literally [`block_verify`], so
//! `Algo::MultiPath { k: 1 }` is bit-identical to `Algo::Block`
//! (test-enforced).

use super::block::{block_verify, block_verify_row0};
use super::dist::{normalize, ProbMatrix};
use super::VerifyOutcome;

/// Result of jointly verifying a `K`-path draft set for one sequence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MultipathOutcome {
    /// Accepted draft tokens of the winning path.
    pub tau: usize,
    /// Index of the winning path within the draft set (the stage that
    /// emitted).
    pub path: usize,
    /// Accepted prefix of the winning path plus the bonus/correction
    /// token; `emitted.len() == tau + 1` always.
    pub emitted: Vec<u32>,
}

impl MultipathOutcome {
    /// Drop the path index, keeping the single-sequence outcome shape.
    pub fn into_outcome(self) -> VerifyOutcome {
        VerifyOutcome { tau: self.tau, emitted: self.emitted }
    }
}

/// Jointly verify `K` candidate draft paths (one entry per path in every
/// slice; `ps[k]` is `(gamma + 1, V)`, `qs[k]` is `(gamma, V)`,
/// `etas[k]` carries path `k`'s `gamma` acceptance uniforms).  `u_final`
/// is the residual-sampling uniform — only the winning stage consumes
/// it, so a single draw suffices for any `K`.
pub fn multipath_verify(
    ps: &[ProbMatrix],
    qs: &[ProbMatrix],
    drafts: &[Vec<u32>],
    etas: &[Vec<f64>],
    u_final: f64,
) -> MultipathOutcome {
    let k = drafts.len();
    assert!(k >= 1, "multipath needs at least one path");
    assert!(
        ps.len() == k && qs.len() == k && etas.len() == k,
        "ragged multipath set: {} ps, {} qs, {} drafts, {} etas",
        ps.len(),
        qs.len(),
        k,
        etas.len()
    );
    let gamma = drafts[0].len();
    assert!(gamma >= 1, "multipath needs gamma >= 1");

    // Remaining position-0 target: starts at M_b(.|c) (row 0 is the same
    // on every path — the paths share the context) and loses one drafter
    // row of mass per fully-rejected stage.  Allocated lazily: the
    // common stage-0-wins case never touches it.
    let mut d: Vec<f64> = Vec::new();
    for stage in 0..k {
        debug_assert_eq!(drafts[stage].len(), gamma, "ragged path lengths");
        debug_assert_eq!(ps[stage].rows, gamma + 1);
        debug_assert_eq!(qs[stage].rows, gamma);
        // One stage = single-path block verification with the remaining
        // target substituted at position 0 (stage 0 substitutes D = row 0
        // itself, so it calls straight through — the k = 1 degradation).
        // The row-0 override variant substitutes without cloning the
        // `(gamma + 1, V)` target matrix.
        let out = if stage == 0 {
            block_verify(&ps[0], &qs[0], &drafts[0], &etas[0], u_final)
        } else {
            block_verify_row0(
                &ps[stage],
                Some(&d),
                &qs[stage],
                &drafts[stage],
                &etas[stage],
                u_final,
            )
        };
        if out.tau >= 1 || stage == k - 1 {
            return MultipathOutcome { tau: out.tau, path: stage, emitted: out.emitted };
        }
        // tau = 0 with paths to spare: fold this stage's position-0
        // drafter row out of the remaining target (Eq. 3 residual at
        // tau = 0) and hand the correction draw to the next path.
        if stage == 0 {
            d = ps[0].row(0).to_vec();
        }
        for (dv, qv) in d.iter_mut().zip(qs[stage].row(0)) {
            *dv = (*dv - qv).max(0.0);
        }
        if !normalize(&mut d) {
            // Degenerate: the remaining target equals the drafter row (up
            // to float dust), so this stage's correction already fell
            // back to sampling D itself — emit it.
            return MultipathOutcome { tau: 0, path: stage, emitted: out.emitted };
        }
    }
    unreachable!("the last stage always returns");
}

/// Jointly verify the `K` leaf paths of one prefix-sharing token tree
/// (DESIGN.md §13.5) — the tree walk of [`multipath_verify`].
///
/// Stage `k` block-verifies the `k`-th leaf's root-to-leaf walk of the
/// node→parent table.  Positions on a shared prefix are *not re-scored*:
/// every leaf passing through a shared node reads the same `node_ps` /
/// `node_qs` rows, so the "skip positions already accepted on a shared
/// prefix" rule is realised structurally — there is one scored row per
/// node, period.  (Under the greedy tau >= 1-wins rule a later stage
/// only ever runs after *every* earlier stage accepted nothing, so there
/// are never previously-accepted positions to re-judge; the skip clause
/// is vacuous at runtime and the dedup is where the tree actually wins.)
///
/// Inputs index the node table directly: `node_ps` row `i` is the target
/// law *at* node `i`, `node_qs` row `i` the drafter law node `i` was
/// sampled from, `ps_root` row 0 the target law at the pending token
/// (verification row 0 of every path).  `etas[k]` carries leaf `k`'s
/// `gamma` acceptance uniforms — the same independent per-path streams
/// as multipath, which is what makes a no-sharing tree bit-identical to
/// [`multipath_verify`] and the residual chain's losslessness carry over
/// verbatim (DESIGN.md §13.4).
pub fn tree_verify(
    ps_root: &ProbMatrix,
    node_ps: &ProbMatrix,
    node_qs: &ProbMatrix,
    tokens: &[u32],
    parent: &[i32],
    leaves: &[usize],
    etas: &[Vec<f64>],
    u_final: f64,
) -> MultipathOutcome {
    let k = leaves.len();
    assert!(k >= 1, "tree verification needs at least one leaf");
    assert_eq!(etas.len(), k, "ragged tree set: {} etas for {k} leaves", etas.len());
    assert!(
        tokens.len() == parent.len()
            && node_ps.rows == tokens.len()
            && node_qs.rows == tokens.len(),
        "ragged node table"
    );

    let mut d: Vec<f64> = Vec::new();
    let mut chain: Vec<usize> = Vec::new();
    for (stage, &leaf) in leaves.iter().enumerate() {
        // Root-to-leaf walk of the parent table (parents precede
        // children, so the reversed ancestor climb is position order).
        chain.clear();
        let mut n = leaf as i32;
        while n >= 0 {
            chain.push(n as usize);
            n = parent[n as usize];
        }
        chain.reverse();
        let drafts: Vec<u32> = chain.iter().map(|&i| tokens[i]).collect();
        let mut ps_rows = Vec::with_capacity(chain.len() + 1);
        ps_rows.push(ps_root.row(0).to_vec());
        for &i in &chain {
            ps_rows.push(node_ps.row(i).to_vec());
        }
        let ps = ProbMatrix::from_rows(ps_rows);
        let qs = ProbMatrix::from_rows(chain.iter().map(|&i| node_qs.row(i).to_vec()).collect());
        // From here the stage body is multipath_verify's, verbatim.
        let out = if stage == 0 {
            block_verify(&ps, &qs, &drafts, &etas[stage], u_final)
        } else {
            block_verify_row0(&ps, Some(&d), &qs, &drafts, &etas[stage], u_final)
        };
        if out.tau >= 1 || stage == k - 1 {
            return MultipathOutcome { tau: out.tau, path: stage, emitted: out.emitted };
        }
        if stage == 0 {
            d = ps_root.row(0).to_vec();
        }
        for (dv, qv) in d.iter_mut().zip(node_qs.row(chain[0])) {
            *dv = (*dv - qv).max(0.0);
        }
        if !normalize(&mut d) {
            return MultipathOutcome { tau: 0, path: stage, emitted: out.emitted };
        }
    }
    unreachable!("the last stage always returns");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, rand_instance};
    use crate::verify::Rng;

    #[test]
    fn k1_is_block_verification_bit_for_bit() {
        check("multipath k=1 == block", 200, |rng| {
            let gamma = 1 + rng.below(6);
            let vocab = 2 + rng.below(12);
            let (ps, qs, drafts) = rand_instance(rng, gamma, vocab, 0.8);
            let etas: Vec<f64> = (0..gamma).map(|_| rng.uniform()).collect();
            let u = rng.uniform();
            let want = block_verify(&ps, &qs, &drafts, &etas, u);
            let got = multipath_verify(
                std::slice::from_ref(&ps),
                std::slice::from_ref(&qs),
                std::slice::from_ref(&drafts),
                std::slice::from_ref(&etas),
                u,
            );
            if got.path != 0 || got.tau != want.tau || got.emitted != want.emitted {
                return Err(format!("{got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn later_path_wins_when_first_rejects() {
        // Path 0 drafts token 0, which the target gives zero mass: the
        // chain dies (p_1 = 0, h = 0) and stage 0 rejects everything.
        // Path 1 drafts token 1 with target mass ~1: always accepted.
        let ps0 = ProbMatrix::from_rows(vec![vec![0.0, 1.0]; 2]);
        let qs0 = ProbMatrix::from_rows(vec![vec![0.9, 0.1]]);
        let ps1 = ProbMatrix::from_rows(vec![vec![0.0, 1.0], vec![0.5, 0.5]]);
        let qs1 = ProbMatrix::from_rows(vec![vec![0.9, 0.1]]);
        let out = multipath_verify(
            &[ps0, ps1],
            &[qs0, qs1],
            &[vec![0], vec![1]],
            &[vec![0.5], vec![0.5]],
            0.3,
        );
        assert_eq!(out.path, 1);
        assert_eq!(out.tau, 1);
        assert_eq!(out.emitted[0], 1);
        assert_eq!(out.emitted.len(), 2);
    }

    #[test]
    fn output_invariants_hold_for_any_k() {
        check("multipath invariants", 200, |rng| {
            let gamma = 1 + rng.below(5);
            let vocab = 2 + rng.below(10);
            let k = 1 + rng.below(4);
            let mut ps = Vec::new();
            let mut qs = Vec::new();
            let mut drafts = Vec::new();
            let mut etas: Vec<Vec<f64>> = Vec::new();
            // Same position-0 rows across paths (the shared-context
            // contract): reuse path 0's rows there.
            for path in 0..k {
                let (mut p, mut q, d) = rand_instance(rng, gamma, vocab, 0.8);
                if path > 0 {
                    p.row_mut(0).copy_from_slice(ps[0].row(0));
                    q.row_mut(0).copy_from_slice(qs[0].row(0));
                }
                ps.push(p);
                qs.push(q);
                drafts.push(d);
                etas.push((0..gamma).map(|_| rng.uniform()).collect());
            }
            let out = multipath_verify(&ps, &qs, &drafts, &etas, rng.uniform());
            if out.path >= k {
                return Err(format!("path {} out of range", out.path));
            }
            if out.emitted.len() != out.tau + 1 {
                return Err(format!("len {} tau {}", out.emitted.len(), out.tau));
            }
            if out.emitted[..out.tau] != drafts[out.path][..out.tau] {
                return Err("accepted prefix differs from the winning path".into());
            }
            if out.emitted.iter().any(|&t| t as usize >= vocab) {
                return Err("token out of vocab".into());
            }
            Ok(())
        });
    }

    #[test]
    fn identical_models_accept_path_zero_fully() {
        // ps == qs everywhere: the chain stays at 1, stage 0 accepts the
        // whole block for any etas < 1.
        let row = vec![0.25; 4];
        let ps = ProbMatrix::from_rows(vec![row.clone(); 3]);
        let qs = ProbMatrix::from_rows(vec![row; 2]);
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let etas = vec![rng.uniform(), rng.uniform()];
            let out = multipath_verify(
                &[ps.clone(), ps.clone()],
                &[qs.clone(), qs.clone()],
                &[vec![1, 2], vec![3, 0]],
                &[etas.clone(), etas],
                rng.uniform(),
            );
            assert_eq!(out.path, 0);
            assert_eq!(out.tau, 2);
            assert_eq!(&out.emitted[..2], &[1, 2]);
        }
    }

    /// Build a disjoint (no-sharing) node table out of a flat multipath
    /// instance: path `p`'s chain occupies nodes `p*gamma .. (p+1)*gamma`.
    fn disjoint_table(
        ps: &[ProbMatrix],
        qs: &[ProbMatrix],
        drafts: &[Vec<u32>],
    ) -> (ProbMatrix, ProbMatrix, ProbMatrix, Vec<u32>, Vec<i32>, Vec<usize>) {
        let gamma = drafts[0].len();
        let ps_root = ProbMatrix::from_rows(vec![ps[0].row(0).to_vec()]);
        let mut p_rows = Vec::new();
        let mut q_rows = Vec::new();
        let mut tokens = Vec::new();
        let mut parent = Vec::new();
        let mut leaves = Vec::new();
        for path in 0..drafts.len() {
            for j in 0..gamma {
                let i = tokens.len();
                p_rows.push(ps[path].row(j + 1).to_vec());
                q_rows.push(qs[path].row(j).to_vec());
                tokens.push(drafts[path][j]);
                parent.push(if j == 0 { -1 } else { i as i32 - 1 });
            }
            leaves.push(tokens.len() - 1);
        }
        (
            ps_root,
            ProbMatrix::from_rows(p_rows),
            ProbMatrix::from_rows(q_rows),
            tokens,
            parent,
            leaves,
        )
    }

    #[test]
    fn tree_verify_on_disjoint_chains_is_multipath_bit_for_bit() {
        check("tree(disjoint) == multipath", 200, |rng| {
            let gamma = 1 + rng.below(5);
            let vocab = 2 + rng.below(10);
            let k = 1 + rng.below(4);
            let mut ps = Vec::new();
            let mut qs = Vec::new();
            let mut drafts = Vec::new();
            let mut etas: Vec<Vec<f64>> = Vec::new();
            for path in 0..k {
                let (mut p, mut q, d) = rand_instance(rng, gamma, vocab, 0.8);
                if path > 0 {
                    p.row_mut(0).copy_from_slice(ps[0].row(0));
                    q.row_mut(0).copy_from_slice(qs[0].row(0));
                }
                ps.push(p);
                qs.push(q);
                drafts.push(d);
                etas.push((0..gamma).map(|_| rng.uniform()).collect());
            }
            let u = rng.uniform();
            let want = multipath_verify(&ps, &qs, &drafts, &etas, u);
            let (pr, np, nq, tokens, parent, leaves) = disjoint_table(&ps, &qs, &drafts);
            let got = tree_verify(&pr, &np, &nq, &tokens, &parent, &leaves, &etas, u);
            if got != want {
                return Err(format!("{got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn tree_verify_shared_prefix_matches_duplicated_paths() {
        // Two leaves sharing the position-0 node vs the same instance
        // flattened with the shared rows duplicated: identical outcomes
        // for every uniform draw (the dedup is pure layout).
        check("tree(shared) == tree(duplicated)", 200, |rng| {
            let vocab = 2 + rng.below(10);
            let gamma = 2 + rng.below(4);
            // One flat 2-path instance whose paths coincide at position 0.
            let (p0, q0, d0) = rand_instance(rng, gamma, vocab, 0.8);
            let (mut p1, mut q1, mut d1) = rand_instance(rng, gamma, vocab, 0.8);
            p1.row_mut(0).copy_from_slice(p0.row(0));
            q1.row_mut(0).copy_from_slice(q0.row(0));
            p1.row_mut(1).copy_from_slice(p0.row(1));
            q1.row_mut(1).copy_from_slice(q0.row(1));
            d1[0] = d0[0];
            let ps = [p0, p1];
            let qs = [q0, q1];
            let drafts = [d0, d1];
            let etas: Vec<Vec<f64>> =
                (0..2).map(|_| (0..gamma).map(|_| rng.uniform()).collect()).collect();
            let u = rng.uniform();

            // Shared table: one depth-0 node, two suffix chains.
            let ps_root = ProbMatrix::from_rows(vec![ps[0].row(0).to_vec()]);
            let mut p_rows = vec![ps[0].row(1).to_vec()];
            let mut q_rows = vec![qs[0].row(0).to_vec()];
            let mut tokens = vec![drafts[0][0]];
            let mut parent = vec![-1i32];
            let mut leaves = Vec::new();
            for path in 0..2 {
                let mut prev = 0i32;
                for j in 1..gamma {
                    let i = tokens.len();
                    p_rows.push(ps[path].row(j + 1).to_vec());
                    q_rows.push(qs[path].row(j).to_vec());
                    tokens.push(drafts[path][j]);
                    parent.push(prev);
                    prev = i as i32;
                }
                leaves.push(prev as usize);
            }
            let shared = tree_verify(
                &ps_root,
                &ProbMatrix::from_rows(p_rows),
                &ProbMatrix::from_rows(q_rows),
                &tokens,
                &parent,
                &leaves,
                &etas,
                u,
            );
            let flat = multipath_verify(&ps, &qs, &drafts, &etas, u);
            if shared != flat {
                return Err(format!("{shared:?} vs {flat:?}"));
            }
            Ok(())
        });
    }
}
