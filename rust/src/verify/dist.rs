//! Distribution utilities shared by the verification algorithms, the
//! simulator and the engine's host-verify path.
//!
//! Probabilities are `f64` on the host path (the device kernels are f32;
//! cross-checking happens through explicit-uniform golden vectors where the
//! decisions are far from the knife edge).

/// Guard against division by an exactly-zero draft probability (the draft
/// sampled the token, so its true probability is positive; zeros only arise
/// from float underflow).
pub const EPS: f64 = 1e-30;

/// A dense row-major matrix of next-token distributions: `rows x vocab`.
#[derive(Clone, Debug)]
pub struct ProbMatrix {
    pub rows: usize,
    pub vocab: usize,
    data: Vec<f64>,
}

impl ProbMatrix {
    pub fn new(rows: usize, vocab: usize) -> Self {
        ProbMatrix { rows, vocab, data: vec![0.0; rows * vocab] }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let vocab = rows.first().map(|r| r.len()).unwrap_or(0);
        let n = rows.len();
        let mut data = Vec::with_capacity(n * vocab);
        for r in &rows {
            assert_eq!(r.len(), vocab, "ragged probability rows");
            data.extend_from_slice(r);
        }
        ProbMatrix { rows: n, vocab, data }
    }

    pub fn from_flat(rows: usize, vocab: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * vocab);
        ProbMatrix { rows, vocab, data }
    }

    /// Build from an f32 slice (device readback path).
    pub fn from_f32(rows: usize, vocab: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * vocab);
        ProbMatrix { rows, vocab, data: data.iter().map(|&x| x as f64).collect() }
    }

    /// Refill from an f32 slice, reusing the existing allocation — the
    /// in-place twin of [`ProbMatrix::from_f32`], used by the persistent
    /// multipath verify scratch ([`crate::draftset::RowViews`]) to avoid
    /// re-allocating `K` matrices per verified row.
    pub fn copy_from_f32(&mut self, rows: usize, vocab: usize, data: &[f32]) {
        assert_eq!(data.len(), rows * vocab);
        self.rows = rows;
        self.vocab = vocab;
        self.data.clear();
        self.data.extend(data.iter().map(|&x| x as f64));
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.vocab..(i + 1) * self.vocab]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.vocab..(i + 1) * self.vocab]
    }
}

/// Inverse-CDF draw over unnormalised non-negative weights.
///
/// Mirrors python `ref._inv_cdf`: `searchsorted(cumsum/total, u*(1-1e-7),
/// side='right')`, i.e. count of cdf entries `<= u'`.
pub fn inv_cdf(weights: &[f64], u: f64) -> usize {
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return 0;
    }
    let target = u * (1.0 - 1e-7) * total;
    let mut acc = 0.0;
    for (i, w) in weights.iter().enumerate() {
        acc += w;
        if acc > target {
            return i;
        }
    }
    weights.len() - 1
}

/// `max(a - b, 0)` elementwise into `out`; returns the sum.
pub fn pos_diff_into(a: &[f64], b: &[f64], out: &mut [f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = (a[i] - b[i]).max(0.0);
        out[i] = d;
        s += d;
    }
    s
}

/// `sum(max(scale*a - b, 0))` without materialising the vector (hot path).
#[inline]
pub fn pos_diff_sum(scale: f64, a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = scale * a[i] - b[i];
        if d > 0.0 {
            s += d;
        }
    }
    s
}

/// Sample from weights, falling back to `fallback` when degenerate
/// (ps == qs exactly leaves an all-zero residual).
pub fn residual_pick(weights: &[f64], fallback: &[f64], u: f64) -> usize {
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        inv_cdf(fallback, u)
    } else {
        inv_cdf(weights, u)
    }
}

/// Total-variation distance between two distributions.
pub fn tv_distance(p: &[f64], q: &[f64]) -> f64 {
    0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

/// Normalise in place; returns false (leaving input untouched) if the sum
/// is non-positive.
pub fn normalize(w: &mut [f64]) -> bool {
    let s: f64 = w.iter().sum();
    if s <= 0.0 {
        return false;
    }
    for x in w.iter_mut() {
        *x /= s;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_cdf_matches_quantiles() {
        let w = [0.1, 0.2, 0.3, 0.4];
        assert_eq!(inv_cdf(&w, 0.05), 0);
        assert_eq!(inv_cdf(&w, 0.15), 1);
        assert_eq!(inv_cdf(&w, 0.95), 3);
        assert_eq!(inv_cdf(&w, 0.999999), 3);
    }

    #[test]
    fn inv_cdf_unnormalised() {
        let w = [1.0, 3.0];
        assert_eq!(inv_cdf(&w, 0.1), 0);
        assert_eq!(inv_cdf(&w, 0.5), 1);
    }

    #[test]
    fn inv_cdf_degenerate() {
        assert_eq!(inv_cdf(&[0.0, 0.0], 0.5), 0);
    }

    #[test]
    fn pos_diff() {
        let mut out = [0.0; 3];
        let s = pos_diff_into(&[0.5, 0.2, 0.3], &[0.1, 0.4, 0.3], &mut out);
        assert!((s - 0.4).abs() < 1e-12);
        assert_eq!(out, [0.4, 0.0, 0.0]);
        assert!((pos_diff_sum(1.0, &[0.5, 0.2, 0.3], &[0.1, 0.4, 0.3]) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn tv_symmetry_and_range() {
        let p = [0.7, 0.3];
        let q = [0.3, 0.7];
        assert!((tv_distance(&p, &q) - 0.4).abs() < 1e-12);
        assert_eq!(tv_distance(&p, &p), 0.0);
    }

    #[test]
    fn prob_matrix_roundtrip() {
        let m = ProbMatrix::from_rows(vec![vec![0.5, 0.5], vec![0.1, 0.9]]);
        assert_eq!(m.row(1), &[0.1, 0.9]);
    }
}
