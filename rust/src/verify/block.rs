//! Paper Algorithm 2 — **block verification**, the paper's contribution.
//!
//! Couples the acceptance of each draft token with the whole block: a
//! running probability `p_i = min(1, p_{i-1} * M_b(X_i|.)/M_s(X_i|.))`
//! (Eq. 8) drives per-length acceptance decisions `h_i` (Eq. 4); unlike
//! token verification the scan never breaks — the final `tau` is the
//! longest accepted sub-block.  Residuals follow Eq. 3 with the `p_tau`
//! coupling.  Theorem 1: lossless; Theorem 2: optimal among valid
//! verification algorithms.

use super::dist::{pos_diff_sum, residual_pick, ProbMatrix, EPS};
use super::VerifyOutcome;

/// Target row `i` with an optional position-0 substitute.  The multipath
/// residual chain (DESIGN.md §9) re-verifies a path against a modified
/// position-0 target `D`; overriding the row view here lets it run the
/// block rule without cloning the whole `(gamma + 1, V)` target matrix
/// to substitute one row.
#[inline]
fn ps_row<'a>(ps: &'a ProbMatrix, row0: Option<&'a [f64]>, i: usize) -> &'a [f64] {
    match row0 {
        Some(r) if i == 0 => r,
        _ => ps.row(i),
    }
}

/// Allocation-free core of the coupled acceptance chain: fills the
/// caller-provided `p`/`h` buffers (each at least `gamma + 1` long) with
/// `p[0] = 1` and, for `i` in `1..=gamma`, `p[i]` per Eq. 8 and `h[i]`
/// per Eq. 4 (`h[gamma] = p[gamma]`).  `h[0]` is an unused sentinel
/// (1.0).  This is the one copy of the chain math, shared by
/// [`block_chain`], [`block_verify`] and [`BlockScratch::verify`] — the
/// engine hot path routes through [`BlockScratch`] buffers instead of
/// allocating two fresh `Vec<f64>` per call.  `row0` optionally
/// substitutes the position-0 target row (see [`block_verify_row0`]).
pub fn block_chain_into_row0(
    ps: &ProbMatrix,
    row0: Option<&[f64]>,
    qs: &ProbMatrix,
    drafts: &[u32],
    p: &mut [f64],
    h: &mut [f64],
) {
    let gamma = drafts.len();
    debug_assert!(p.len() > gamma && h.len() > gamma, "chain buffers too short");
    if let Some(r) = row0 {
        debug_assert_eq!(r.len(), ps.vocab, "row0 vocab mismatch");
    }
    p[0] = 1.0;
    h[0] = 1.0;
    for i in 1..=gamma {
        let x = drafts[i - 1] as usize;
        let ratio = ps_row(ps, row0, i - 1)[x] / qs.row(i - 1)[x].max(EPS);
        p[i] = (p[i - 1] * ratio).min(1.0);
        if i == gamma {
            h[i] = p[i];
        } else {
            let s_i = pos_diff_sum(p[i], ps.row(i), qs.row(i));
            let denom = s_i + 1.0 - p[i];
            h[i] = if denom <= EPS { 1.0 } else { s_i / denom };
        }
    }
}

/// [`block_chain_into_row0`] with the unmodified target matrix.
pub fn block_chain_into(
    ps: &ProbMatrix,
    qs: &ProbMatrix,
    drafts: &[u32],
    p: &mut [f64],
    h: &mut [f64],
) {
    block_chain_into_row0(ps, None, qs, drafts, p, h);
}

/// The coupled acceptance chain as freshly allocated vectors — the
/// convenience wrapper over [`block_chain_into`] used by tests and the
/// golden-vector harness.
pub fn block_chain(ps: &ProbMatrix, qs: &ProbMatrix, drafts: &[u32]) -> (Vec<f64>, Vec<f64>) {
    let gamma = drafts.len();
    let mut p = vec![1.0; gamma + 1];
    let mut h = vec![1.0; gamma + 1];
    block_chain_into(ps, qs, drafts, &mut p, &mut h);
    (p, h)
}

/// [`block_verify`] with an optional position-0 target-row override:
/// `row0 = Some(d)` verifies the block exactly as if `ps.row(0)` were
/// `d`, without materialising the substituted matrix.  This is the
/// multipath residual chain's workhorse ([`super::multipath_verify`]):
/// every rejected stage folds drafter mass out of the remaining
/// position-0 target and re-runs the block rule against the result —
/// previously a full `(gamma + 1, V)` clone per stage.
pub fn block_verify_row0(
    ps: &ProbMatrix,
    row0: Option<&[f64]>,
    qs: &ProbMatrix,
    drafts: &[u32],
    etas: &[f64],
    u_final: f64,
) -> VerifyOutcome {
    let gamma = drafts.len();
    debug_assert_eq!(ps.rows, gamma + 1);
    debug_assert_eq!(qs.rows, gamma);
    let mut p = vec![1.0; gamma + 1];
    let mut h = vec![1.0; gamma + 1];
    block_chain_into_row0(ps, row0, qs, drafts, &mut p, &mut h);
    // Longest accepted sub-block: no break, keep the max accepted index.
    let mut tau = 0;
    for i in 1..=gamma {
        if etas[i - 1] <= h[i] {
            tau = i;
        }
    }
    let y = if tau == gamma {
        residual_pick(ps.row(gamma), ps.row(gamma), u_final)
    } else {
        // Eq. 3: residual ~ norm(max(p_tau * M_b - M_s, 0)).
        let mut res = vec![0.0; ps.vocab];
        let pr = ps_row(ps, row0, tau);
        let qr = qs.row(tau);
        for v in 0..ps.vocab {
            res[v] = (p[tau] * pr[v] - qr[v]).max(0.0);
        }
        residual_pick(&res, pr, u_final)
    };
    let mut emitted: Vec<u32> = drafts[..tau].to_vec();
    emitted.push(y as u32);
    VerifyOutcome { tau, emitted }
}

/// Verify a draft block jointly (Algorithm 2).  Same signature/semantics as
/// [`super::token::token_verify`] — a drop-in replacement, as the paper
/// stresses.
pub fn block_verify(
    ps: &ProbMatrix,
    qs: &ProbMatrix,
    drafts: &[u32],
    etas: &[f64],
    u_final: f64,
) -> VerifyOutcome {
    block_verify_row0(ps, None, qs, drafts, etas, u_final)
}

/// Scratch-buffer variant for the engine hot path: avoids the per-call
/// `Vec` allocations of [`block_verify`] (see EXPERIMENTS.md §Perf).
pub struct BlockScratch {
    p: Vec<f64>,
    h: Vec<f64>,
    res: Vec<f64>,
}

impl BlockScratch {
    pub fn new(gamma: usize, vocab: usize) -> Self {
        BlockScratch { p: vec![0.0; gamma + 1], h: vec![0.0; gamma + 1], res: vec![0.0; vocab] }
    }

    pub fn verify(
        &mut self,
        ps: &ProbMatrix,
        qs: &ProbMatrix,
        drafts: &[u32],
        etas: &[f64],
        u_final: f64,
        emitted: &mut Vec<u32>,
    ) -> usize {
        let gamma = drafts.len();
        block_chain_into(ps, qs, drafts, &mut self.p, &mut self.h);
        let mut tau = 0;
        for i in 1..=gamma {
            if etas[i - 1] <= self.h[i] {
                tau = i;
            }
        }
        let y = if tau == gamma {
            residual_pick(ps.row(gamma), ps.row(gamma), u_final)
        } else {
            let sum = {
                let pr = ps.row(tau);
                let qr = qs.row(tau);
                let mut s = 0.0;
                for v in 0..ps.vocab {
                    let d = (self.p[tau] * pr[v] - qr[v]).max(0.0);
                    self.res[v] = d;
                    s += d;
                }
                s
            };
            if sum <= 0.0 {
                residual_pick(ps.row(tau), ps.row(tau), u_final)
            } else {
                super::dist::inv_cdf(&self.res[..ps.vocab], u_final)
            }
        };
        emitted.clear();
        emitted.extend_from_slice(&drafts[..tau]);
        emitted.push(y as u32);
        tau
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: Vec<Vec<f64>>) -> ProbMatrix {
        ProbMatrix::from_rows(rows)
    }

    #[test]
    fn chain_is_clamped_and_monotone_under_min() {
        let ps = mat(vec![vec![0.9, 0.1]; 4]);
        let qs = mat(vec![vec![0.1, 0.9]; 3]);
        let (p, _) = block_chain(&ps, &qs, &[0, 0, 0]);
        assert_eq!(p[0], 1.0);
        for &pi in &p {
            assert!((0.0..=1.0).contains(&pi));
        }
        // ratio 9 each step but clamped at 1.
        assert_eq!(p[1], 1.0);
        assert_eq!(p[3], 1.0);
    }

    #[test]
    fn no_early_break_can_accept_later_tokens() {
        // Construct: token 1 rejected (eta > h_1) but token 2's h_2 can
        // still fire, yielding tau = 2 — impossible for token verification.
        let ps = mat(vec![vec![0.25, 0.75], vec![0.9, 0.1], vec![0.5, 0.5]]);
        let qs = mat(vec![vec![0.5, 0.5], vec![0.1, 0.9]]);
        // X1 = 0: ratio 0.5 -> p1 = 0.5. S1 = max(.5*.9-.1,0)+max(.5*.1-.9,0)
        // = 0.35; h1 = 0.35/(0.35+0.5) ~ 0.41. eta1 = 0.9 rejects length 1.
        // X2 = 0: ratio = .9/.1 = 9 -> p2 = min(0.5*9,1) = 1 -> h2 = 1:
        // accepts length 2 regardless of eta2.
        let out = block_verify(&ps, &qs, &[0, 0], &[0.9, 0.5], 0.2);
        assert_eq!(out.tau, 2);
        assert_eq!(&out.emitted[..2], &[0, 0]);
    }

    #[test]
    fn row0_override_matches_cloned_substitution() {
        let ps = mat(vec![
            vec![0.2, 0.3, 0.5],
            vec![0.6, 0.2, 0.2],
            vec![0.1, 0.1, 0.8],
        ]);
        let qs = mat(vec![vec![0.3, 0.3, 0.4], vec![0.2, 0.5, 0.3]]);
        let drafts = [2u32, 0];
        let d = vec![0.7, 0.2, 0.1];
        for seed in 0..50 {
            let mut rng = crate::verify::rng::Rng::new(seed);
            let etas = [rng.uniform(), rng.uniform()];
            let u = rng.uniform();
            let mut ps_mod = ps.clone();
            ps_mod.row_mut(0).copy_from_slice(&d);
            let want = block_verify(&ps_mod, &qs, &drafts, &etas, u);
            let got = block_verify_row0(&ps, Some(&d), &qs, &drafts, &etas, u);
            assert_eq!(want, got, "seed {seed}");
            // And with no override, the plain block rule.
            let plain = block_verify(&ps, &qs, &drafts, &etas, u);
            assert_eq!(plain, block_verify_row0(&ps, None, &qs, &drafts, &etas, u));
        }
    }

    #[test]
    fn scratch_matches_alloc_version() {
        let ps = mat(vec![
            vec![0.2, 0.3, 0.5],
            vec![0.6, 0.2, 0.2],
            vec![0.1, 0.1, 0.8],
        ]);
        let qs = mat(vec![vec![0.3, 0.3, 0.4], vec![0.2, 0.5, 0.3]]);
        let drafts = [2u32, 1];
        for seed in 0..50 {
            let mut rng = crate::verify::rng::Rng::new(seed);
            let etas = [rng.uniform(), rng.uniform()];
            let u = rng.uniform();
            let a = block_verify(&ps, &qs, &drafts, &etas, u);
            let mut scratch = BlockScratch::new(2, 3);
            let mut em = Vec::new();
            let tau = scratch.verify(&ps, &qs, &drafts, &etas, u, &mut em);
            assert_eq!(a.tau, tau);
            assert_eq!(a.emitted, em);
        }
    }
}
