//! Paper Appendix C — greedy block verification (Algorithm 4) plus the
//! distribution-modification bookkeeping of Algorithms 5/6.
//!
//! Greedy verification accepts strictly more tokens *per iteration* than
//! block verification (Theorem 3) but requires the target distribution at
//! the first `gamma - tau - 1` positions of the *next* iteration to be
//! replaced per Algorithm 5 (Eq. 23), which hurts future acceptance; the
//! paper finds it empirically worse end-to-end (Table 3) and recommends
//! block verification.  We implement it to reproduce Table 3.
//!
//! Eq. 23 defines the modified target through *joint* sequence
//! probabilities: `M_new(x_i|.) ∝ max(M_b(c, X^tau, Y, x^i) -
//! M_s(c, X^tau, Y, x^i), 0)`.  Factoring the joints, the modified row at a
//! window position is `norm(max(M_row - R * Ms_row, 0))` with `R` the
//! running ratio `Ms_joint / M_joint` accumulated along every token emitted
//! since the window opened (`M` = the composite target the window was
//! created against).  Algorithm 6 re-modifies the *current* composite on
//! each rejection, so windows nest; per-sequence state is a list of
//! [`Layer`]s, oldest first.  (Mirrors python ref.greedy_verify; checked
//! draw-for-draw via golden vectors.)

use super::dist::{inv_cdf, normalize, ProbMatrix, EPS};
use super::VerifyOutcome;

/// One active modification window.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Layer {
    /// How many upcoming positions this window still covers.
    pub remaining: usize,
    /// Running `Ms_joint / M_joint` ratio since the window opened.
    pub ratio: f64,
}

/// Per-sequence greedy verification state (Algorithm 6 line 6).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GreedyState {
    pub layers: Vec<Layer>,
}

impl GreedyState {
    pub fn new(_gamma: usize) -> Self {
        GreedyState { layers: Vec::new() }
    }
}

fn norm_or(row: &mut [f64], fallback: &[f64]) {
    if !normalize(row) {
        row.copy_from_slice(fallback);
    }
}

/// Greedy block verification (Algorithm 4) under the modified target
/// dictated by `state` (Algorithms 5/6).  Returns the outcome and the new
/// state for the next iteration.
pub fn greedy_verify(
    ps: &ProbMatrix,
    qs: &ProbMatrix,
    drafts: &[u32],
    etas: &[f64],
    u_final: f64,
    state: &GreedyState,
) -> (VerifyOutcome, GreedyState) {
    let gamma = drafts.len();
    debug_assert_eq!(ps.rows, gamma + 1);
    debug_assert_eq!(qs.rows, gamma);
    let v = ps.vocab;
    let n_layers = state.layers.len();

    // Walk positions 0..=gamma: composite rows, below-layer rows and ratio
    // snapshots along the draft path.
    let mut comp: Vec<Vec<f64>> = Vec::with_capacity(gamma + 1);
    let mut below: Vec<Vec<Vec<f64>>> = Vec::with_capacity(gamma + 1);
    let mut ratio_snap: Vec<Vec<f64>> = Vec::with_capacity(gamma + 1);
    let mut cur_r: Vec<f64> = state.layers.iter().map(|l| l.ratio).collect();
    for i in 0..=gamma {
        let mut row = ps.row(i).to_vec();
        let mut below_i: Vec<Vec<f64>> = Vec::with_capacity(n_layers);
        for (l, layer) in state.layers.iter().enumerate() {
            below_i.push(row.clone());
            if layer.remaining > i && i < gamma {
                let q = qs.row(i);
                for x in 0..v {
                    row[x] = (row[x] - cur_r[l] * q[x]).max(0.0);
                }
                let q_owned = q.to_vec();
                norm_or(&mut row, &q_owned);
            }
        }
        comp.push(row);
        ratio_snap.push(cur_r.clone());
        if i < gamma {
            let x = drafts[i] as usize;
            for (l, layer) in state.layers.iter().enumerate() {
                if layer.remaining > i {
                    cur_r[l] *= qs.row(i)[x] / below_i[l][x].max(EPS);
                }
            }
        }
        below.push(below_i);
    }

    // Algorithm 4 proper, against the composite rows.
    let mut ptilde = vec![1.0; gamma + 1];
    let mut tau = 0usize;
    for i in 1..gamma {
        let x = drafts[i - 1] as usize;
        ptilde[i] = ptilde[i - 1] * comp[i - 1][x] / qs.row(i - 1)[x].max(EPS);
        let (mut p_remain, mut p_rej) = (0.0, 0.0);
        let q = qs.row(i);
        for x2 in 0..v {
            let d = ptilde[i] * comp[i][x2] - q[x2];
            if d > 0.0 {
                p_remain += d;
            } else {
                p_rej -= d;
            }
        }
        let h_i = if p_rej <= EPS { 1.0 } else { (p_remain / p_rej).min(1.0) };
        if etas[i - 1] <= h_i {
            tau = i;
        }
    }
    {
        let x = drafts[gamma - 1] as usize;
        ptilde[gamma] = ptilde[gamma - 1] * comp[gamma - 1][x] / qs.row(gamma - 1)[x].max(EPS);
    }
    let y: usize;
    if etas[gamma - 1] <= ptilde[gamma] {
        tau = gamma;
        y = inv_cdf(&comp[gamma], u_final);
    } else {
        let q = qs.row(tau);
        let mut res = vec![0.0; v];
        let mut s = 0.0;
        for x in 0..v {
            let d = (ptilde[tau] * comp[tau][x] - q[x]).max(0.0);
            res[x] = d;
            s += d;
        }
        y = if s <= 0.0 { inv_cdf(&comp[tau], u_final) } else { inv_cdf(&res, u_final) };
    }

    // Next-iteration layer state: survivors (ratios advanced through
    // X^tau and Y) plus the freshly opened window.
    let mut new_state = GreedyState::default();
    for (l, layer) in state.layers.iter().enumerate() {
        if layer.remaining <= tau + 1 {
            continue; // expired
        }
        let mut r = ratio_snap[tau][l];
        if tau < gamma {
            r *= qs.row(tau)[y] / below[tau][l][y].max(EPS);
        }
        new_state.layers.push(Layer { remaining: layer.remaining - (tau + 1), ratio: r });
    }
    if tau < gamma && gamma - tau - 1 > 0 {
        let mut r_new = 1.0;
        for i in 0..tau {
            let xi = drafts[i] as usize;
            r_new *= qs.row(i)[xi] / comp[i][xi].max(EPS);
        }
        r_new *= qs.row(tau)[y] / comp[tau][y].max(EPS);
        new_state.layers.push(Layer { remaining: gamma - tau - 1, ratio: r_new });
    }

    let mut emitted: Vec<u32> = drafts[..tau].to_vec();
    emitted.push(y as u32);
    (VerifyOutcome { tau, emitted }, new_state)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: Vec<Vec<f64>>) -> ProbMatrix {
        ProbMatrix::from_rows(rows)
    }

    #[test]
    fn bernoulli_example_acceptance() {
        // Section 2 example: Mb = (1/3, 2/3), Ms = (2/3, 1/3), gamma = 2.
        let ps = mat(vec![vec![1.0 / 3.0, 2.0 / 3.0]; 3]);
        let qs = mat(vec![vec![2.0 / 3.0, 1.0 / 3.0]; 2]);
        let st = GreedyState::new(2);
        // AA with eta2 just under ptilde_2 = 1/4 -> accepted fully.
        let (out, _) = greedy_verify(&ps, &qs, &[0, 0], &[0.9, 0.24], 0.1, &st);
        assert_eq!(out.tau, 2);
        // AA with eta2 over 1/4: everything rejected, Y forced to B,
        // window of 1 position opens with ratio Ms(B)/Mb(B) = 1/2.
        let (out, st2) = greedy_verify(&ps, &qs, &[0, 0], &[0.9, 0.9], 0.1, &st);
        assert_eq!(out.tau, 0);
        assert_eq!(out.emitted, vec![1]);
        assert_eq!(st2.layers.len(), 1);
        assert_eq!(st2.layers[0].remaining, 1);
        assert!((st2.layers[0].ratio - 0.5).abs() < 1e-12, "{:?}", st2);
    }

    #[test]
    fn window_forces_modified_distribution() {
        // Continue the example: with the (1, 1/2) window active, the
        // composite at position 0 is the point mass on B, so a drafted A is
        // always rejected and Y = B again; the NEW window ratio is
        // Ms(B)/M_comp(B) = (1/3)/1 = 1/3 (paper appendix C walk-through).
        let ps = mat(vec![vec![1.0 / 3.0, 2.0 / 3.0]; 3]);
        let qs = mat(vec![vec![2.0 / 3.0, 1.0 / 3.0]; 2]);
        let st = GreedyState { layers: vec![Layer { remaining: 1, ratio: 0.5 }] };
        let (out, st2) = greedy_verify(&ps, &qs, &[0, 0], &[0.5, 0.5], 0.3, &st);
        assert_eq!(out.tau, 0);
        assert_eq!(out.emitted, vec![1]);
        assert_eq!(st2.layers.len(), 1);
        assert!((st2.layers[0].ratio - 1.0 / 3.0).abs() < 1e-12, "{:?}", st2);
    }

    #[test]
    fn full_acceptance_leaves_clean_state() {
        let ps = mat(vec![vec![0.5, 0.5]; 3]);
        let qs = mat(vec![vec![0.5, 0.5]; 2]);
        let st = GreedyState::new(2);
        let (out, st2) = greedy_verify(&ps, &qs, &[0, 1], &[0.4, 0.4], 0.2, &st);
        assert_eq!(out.tau, 2);
        assert!(st2.layers.is_empty());
    }

    #[test]
    fn layer_count_is_bounded_by_gamma() {
        let mut st = GreedyState::new(4);
        let ps = mat(vec![vec![0.7, 0.1, 0.1, 0.1]; 5]);
        let qs = mat(vec![vec![0.1, 0.1, 0.1, 0.7]; 4]);
        let mut rng = crate::verify::Rng::new(3);
        for _ in 0..200 {
            let drafts = [3u32, 3, 3, 3];
            let etas: Vec<f64> = (0..4).map(|_| rng.uniform()).collect();
            let (_, st2) = greedy_verify(&ps, &qs, &drafts, &etas, rng.uniform(), &st);
            st = st2;
            assert!(st.layers.len() <= 3, "{:?}", st);
        }
    }
}
