//! Draft-verification algorithms (the paper's subject) on the host path.
//!
//! The device path runs the same math as Pallas kernels fused into the
//! `spec_iter_*` HLO programs (python/compile/kernels/verify.py); this
//! module powers the host-verify engine mode (needed for greedy
//! verification, Appendix C), the distribution-level simulator, and all
//! rust-side property tests.  Cross-layer agreement is enforced by the
//! golden vectors in `artifacts/golden_verify.json` (see rust/tests/).

pub mod block;
pub mod dist;
pub mod greedy;
pub mod multipath;
pub mod rng;
pub mod token;

pub use block::{
    block_chain, block_chain_into, block_chain_into_row0, block_verify, block_verify_row0,
    BlockScratch,
};
pub use dist::ProbMatrix;
pub use greedy::{greedy_verify, GreedyState};
pub use greedy::Layer;
pub use multipath::{multipath_verify, tree_verify, MultipathOutcome};
pub use rng::Rng;
pub use token::token_verify;

/// Result of verifying one draft block: `tau` accepted draft tokens plus
/// the bonus/correction token — `emitted.len() == tau + 1` always.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VerifyOutcome {
    pub tau: usize,
    pub emitted: Vec<u32>,
}

/// Which verification algorithm to run (paper Algorithms 1, 2, 4, plus
/// the multi-draft extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Algorithm 1 — standard token verification (Leviathan et al. 2022).
    Token,
    /// Algorithm 2 — block verification (the paper's contribution).
    Block,
    /// Algorithm 4 + 5/6 — greedy block verification (Appendix C).
    Greedy,
    /// Joint block verification over `k` independently drafted candidate
    /// paths ([`multipath`], DESIGN.md §9); bit-identical to
    /// [`Algo::Block`] at `k == 1` (test-enforced).
    MultiPath { k: usize },
    /// Prefix-sharing token-tree speculation over `k` leaves
    /// ([`tree_verify`], DESIGN.md §13): the same `k` independent draft
    /// streams as [`Algo::MultiPath`], but coincident prefixes are
    /// drafted, stored, and target-scored once.  Bit-identical to
    /// `MultiPath { k }` end to end (and hence to [`Algo::Block`] at
    /// `k == 1`), with strictly fewer drafted tokens scored whenever
    /// draws coincide (both test-enforced).
    Tree { k: usize },
}

impl Algo {
    pub fn name(self) -> &'static str {
        match self {
            Algo::Token => "token",
            Algo::Block => "block",
            Algo::Greedy => "greedy",
            Algo::MultiPath { .. } => "multipath",
            Algo::Tree { .. } => "tree",
        }
    }

    /// Parse an algorithm name; multipath and tree take an optional path
    /// count (`"multipath"` = 2 paths, `"multipath:4"` = 4, likewise
    /// `"tree"`/`"tree:<k>"`).
    pub fn parse(s: &str) -> Option<Algo> {
        if let Some(ks) = s.strip_prefix("multipath:") {
            return ks.parse::<usize>().ok().filter(|&k| k >= 1).map(|k| Algo::MultiPath { k });
        }
        if let Some(ks) = s.strip_prefix("tree:") {
            return ks.parse::<usize>().ok().filter(|&k| k >= 1).map(|k| Algo::Tree { k });
        }
        match s {
            "token" => Some(Algo::Token),
            "block" => Some(Algo::Block),
            "greedy" => Some(Algo::Greedy),
            "multipath" => Some(Algo::MultiPath { k: 2 }),
            "tree" => Some(Algo::Tree { k: 2 }),
            _ => None,
        }
    }

    /// Candidate draft paths per iteration (1 for the single-draft
    /// algorithms).
    pub fn paths(self) -> usize {
        match self {
            Algo::MultiPath { k } | Algo::Tree { k } => k,
            _ => 1,
        }
    }

    /// The fused in-backend variants; greedy requires host verification
    /// (it threads distribution-modification state across iterations).
    pub fn fused(self) -> bool {
        !matches!(self, Algo::Greedy)
    }
}

impl std::fmt::Display for Algo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Algo::MultiPath { k } => write!(f, "multipath:{k}"),
            Algo::Tree { k } => write!(f, "tree:{k}"),
            _ => f.write_str(self.name()),
        }
    }
}

/// Dispatch on a stateless algorithm over a *single* draft path.  Greedy
/// needs [`GreedyState`]; use [`greedy_verify`] directly.  A lone path of
/// a multipath set is verified by the block rule (the `k = 1`
/// degradation); joint `K`-path verification is [`multipath_verify`].
pub fn verify(
    algo: Algo,
    ps: &ProbMatrix,
    qs: &ProbMatrix,
    drafts: &[u32],
    etas: &[f64],
    u_final: f64,
) -> VerifyOutcome {
    match algo {
        Algo::Token => token_verify(ps, qs, drafts, etas, u_final),
        Algo::Block | Algo::MultiPath { .. } | Algo::Tree { .. } => {
            block_verify(ps, qs, drafts, etas, u_final)
        }
        Algo::Greedy => {
            greedy_verify(ps, qs, drafts, etas, u_final, &GreedyState::new(drafts.len())).0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_roundtrip() {
        for a in [Algo::Token, Algo::Block, Algo::Greedy] {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("bogus"), None);
        assert!(Algo::Token.fused() && Algo::Block.fused() && !Algo::Greedy.fused());
    }

    #[test]
    fn multipath_parse_display_paths() {
        assert_eq!(Algo::parse("multipath"), Some(Algo::MultiPath { k: 2 }));
        assert_eq!(Algo::parse("multipath:4"), Some(Algo::MultiPath { k: 4 }));
        assert_eq!(Algo::parse("multipath:1"), Some(Algo::MultiPath { k: 1 }));
        assert_eq!(Algo::parse("multipath:0"), None);
        assert_eq!(Algo::parse("multipath:x"), None);
        let a = Algo::MultiPath { k: 4 };
        assert_eq!(a.to_string(), "multipath:4");
        assert_eq!(a.name(), "multipath");
        assert_eq!(a.paths(), 4);
        assert_eq!(Algo::Block.paths(), 1);
        assert!(a.fused());
        // Display round-trips through parse for any k.
        assert_eq!(Algo::parse(&a.to_string()), Some(a));
    }

    #[test]
    fn tree_parse_display_paths() {
        assert_eq!(Algo::parse("tree"), Some(Algo::Tree { k: 2 }));
        assert_eq!(Algo::parse("tree:4"), Some(Algo::Tree { k: 4 }));
        assert_eq!(Algo::parse("tree:1"), Some(Algo::Tree { k: 1 }));
        assert_eq!(Algo::parse("tree:0"), None);
        assert_eq!(Algo::parse("tree:x"), None);
        let a = Algo::Tree { k: 4 };
        assert_eq!(a.to_string(), "tree:4");
        assert_eq!(a.name(), "tree");
        assert_eq!(a.paths(), 4);
        assert!(a.fused());
        assert_eq!(Algo::parse(&a.to_string()), Some(a));
    }

    /// gamma = 1 block verification degenerates to token verification
    /// (the paper notes the two algorithms coincide at gamma = 1).
    #[test]
    fn gamma1_block_equals_token() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let v = 4;
            let mk = |rng: &mut Rng| {
                let mut w: Vec<f64> = (0..v).map(|_| rng.uniform() + 0.01).collect();
                dist::normalize(&mut w);
                w
            };
            let ps = ProbMatrix::from_rows(vec![mk(&mut rng), mk(&mut rng)]);
            let qs = ProbMatrix::from_rows(vec![mk(&mut rng)]);
            let draft = [rng.below(v) as u32];
            let etas = [rng.uniform()];
            let u = rng.uniform();
            let t = token_verify(&ps, &qs, &draft, &etas, u);
            let b = block_verify(&ps, &qs, &draft, &etas, u);
            assert_eq!(t, b);
        }
    }
}
