//! Configuration system: one JSON file (or defaults) drives the launcher,
//! the engine, the server and the experiment harness.  Decoded with the
//! in-tree parser (util::json); unknown fields are ignored, missing fields
//! fall back to defaults, so partial configs compose cleanly.
//!
//! ```json
//! {
//!   "engine":      {"gamma": 8, "algo": "block", "drafter": "xxs",
//!                   "max_new_tokens": 48},
//!   "server":      {"addr": "127.0.0.1:8377", "queue_limit": 1024},
//!   "experiments": {"prompts_per_dataset": 64, "seeds": [0, 1, 2]}
//! }
//! ```
//!
//! Multi-draft speculation selects `"algo": "multipath"` /
//! `"multipath:<k>"` or `"algo": "tree"` / `"tree:<k>"` (prefix-sharing
//! token tree, DESIGN.md §13); an optional `"paths": <k>` field overrides
//! the path count for either and is ignored (with a warning) for
//! single-draft algorithms.
//!
//! Engine knobs funnel through [`EngineConfigBuilder`]: both the JSON
//! layer and programmatic construction go through
//! [`EngineConfigBuilder::build`], the single place that validates and
//! warns (on stderr) about inconsistent engine settings.  The one knob
//! that stays backend-level is the tree branch threshold
//! (`NativeBackend::with_branch_threshold` / `SPECD_TREE_THRESHOLD`): it
//! tunes drafting cost, never the committed distribution, so it belongs
//! to the backend that owns the drafter.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::backend::{KvLayout, Precision};
use crate::util::json::Value;
use crate::verify::Algo;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Draft block length (paper gamma).
    pub gamma: usize,
    /// Verification algorithm.
    pub algo: Algo,
    /// Drafter variant name ("xxs" | "xxxs").
    pub drafter: String,
    /// Per-request generation cap (the paper uses 128; our scaled default
    /// fits the CPU substrate — see DESIGN.md §8).
    pub max_new_tokens: usize,
    /// Verification location: fused in-HLO kernels or host-side rust
    /// (required for greedy; also used for cross-checks).
    pub host_verify: bool,
    /// RNG seed feeding per-iteration device seeds.
    pub seed: u64,
    /// Draft-model inference precision (`"int8"` | `"fp32"`,
    /// DESIGN.md §11).  Default: env `SPECD_DRAFT_PRECISION`, else int8 —
    /// verification corrects any drafter drift, so the quantised draft
    /// cannot change the committed-token distribution.  The target model
    /// always runs fp32; backends without a quantised path (PJRT) serve
    /// the draft in fp32 regardless.
    pub draft_precision: Precision,
    /// Online speculation controller (DESIGN.md §15).  Off by default so
    /// existing streams stay bit-identical; `SPECD_ADAPTIVE=on` or the
    /// JSON `"adaptive"` block opts in.
    pub adaptive: AdaptiveConfig,
    /// Native KV cache layout (`"paged"` | `"contig"`, DESIGN.md §16).
    /// Default: env `SPECD_KV_LAYOUT`, else paged — the scatter-paged
    /// arena is bit-identical to the contiguous rings (test-enforced), so
    /// the layout can never change the committed-token distribution;
    /// contig remains the oracle for the identity tests.  Backends that
    /// allocate their own KV (PJRT) ignore this knob.
    pub kv_layout: KvLayout,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            gamma: 8,
            algo: Algo::Block,
            drafter: "xxs".into(),
            max_new_tokens: 48,
            host_verify: false,
            seed: 0,
            draft_precision: Precision::from_env_or_default(),
            adaptive: AdaptiveConfig::default(),
            kv_layout: KvLayout::from_env_or_default(),
        }
    }
}

/// Knobs for the per-slot adaptive speculation controller
/// ([`crate::control::Controller`], DESIGN.md §15).  The controller only
/// retunes gamma (and the path count K for multi-draft algorithms) —
/// both are losslessness-invariant, so no setting here can change the
/// committed-token distribution (test-enforced in `tests/theorems.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptiveConfig {
    /// Master switch.  Default: env `SPECD_ADAPTIVE` (`on`/`off`), else
    /// off — adaptive-off streams are bit-identical to pre-controller
    /// builds.
    pub enabled: bool,
    /// Sliding acceptance window, in speculation iterations per slot.
    pub window: usize,
    /// Observations before the controller trusts its estimate and leaves
    /// the configured gamma (a fresh slot should not thrash on noise).
    pub min_window: usize,
    /// Inclusive gamma search band.
    pub gamma_min: usize,
    /// Inclusive gamma search band; also the batch layout bound the
    /// serving tier reserves room for.
    pub gamma_max: usize,
    /// Relative improvement a challenger arm must show over the incumbent
    /// before the controller switches (suppresses estimate-noise flapping).
    pub hysteresis: f64,
    /// Pinned draft/target per-token cost ratio for the objective;
    /// `None` = measure online from the engine's forward timings.  CI
    /// gates pin it for determinism.
    pub cost_ratio: Option<f64>,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            enabled: adaptive_env_default(),
            window: 32,
            min_window: 4,
            gamma_min: 2,
            gamma_max: 8,
            hysteresis: 0.15,
            cost_ratio: None,
        }
    }
}

/// Strict parse of an `SPECD_ADAPTIVE`-style toggle; `None` = unknown.
fn adaptive_flag(s: &str) -> Option<bool> {
    match s.trim().to_ascii_lowercase().as_str() {
        "1" | "on" | "true" | "yes" => Some(true),
        "" | "0" | "off" | "false" | "no" => Some(false),
        _ => None,
    }
}

/// `SPECD_ADAPTIVE` env toggle.  Mirrors `SPECD_DRAFT_PRECISION`'s
/// convention: an invalid value warns on stderr and falls back to the
/// default (off) instead of erroring.
fn adaptive_env_default() -> bool {
    match std::env::var("SPECD_ADAPTIVE") {
        Ok(s) => adaptive_flag(&s).unwrap_or_else(|| {
            eprintln!("specd: ignoring invalid SPECD_ADAPTIVE '{s}' (on | off); using off");
            false
        }),
        Err(_) => false,
    }
}

impl EngineConfig {
    /// Greedy verification only exists on the host-verify path.
    pub fn effective_host_verify(&self) -> bool {
        self.host_verify || !self.algo.fused()
    }

    /// Start a builder from the defaults.
    pub fn builder() -> EngineConfigBuilder {
        EngineConfig::default().to_builder()
    }

    /// Start a builder from this config (the JSON layer uses this so that
    /// partial configs revalidate against what they override).
    pub fn to_builder(&self) -> EngineConfigBuilder {
        EngineConfigBuilder { cfg: self.clone(), paths: None }
    }

    fn apply(&mut self, v: &Value) -> Result<()> {
        let mut b = self.to_builder();
        if let Some(x) = v.get("gamma").and_then(Value::as_usize) {
            b = b.gamma(x);
        }
        if let Some(x) = v.get("algo").and_then(Value::as_str) {
            b = b.algo(Algo::parse(x).ok_or_else(|| anyhow!("unknown algo '{x}'"))?);
        }
        if let Some(x) = v.get("paths").and_then(Value::as_usize) {
            b = b.paths(x);
        }
        if let Some(x) = v.get("drafter").and_then(Value::as_str) {
            b = b.drafter(x);
        }
        if let Some(x) = v.get("max_new_tokens").and_then(Value::as_usize) {
            b = b.max_new_tokens(x);
        }
        if let Some(x) = v.get("host_verify").and_then(Value::as_bool) {
            b = b.host_verify(x);
        }
        if let Some(x) = v.get("seed").and_then(Value::as_u64) {
            b = b.seed(x);
        }
        if let Some(x) = v.get("draft_precision").and_then(Value::as_str) {
            b = b.draft_precision(
                Precision::parse(x)
                    .ok_or_else(|| anyhow!("unknown draft_precision '{x}' (int8 | fp32)"))?,
            );
        }
        if let Some(x) = v.get("kv_layout").and_then(Value::as_str) {
            b = b.kv_layout(
                KvLayout::parse(x)
                    .ok_or_else(|| anyhow!("unknown kv_layout '{x}' (contig | paged)"))?,
            );
        }
        if let Some(a) = v.get("adaptive") {
            let mut ac = self.adaptive.clone();
            if let Some(x) = a.get("enabled").and_then(Value::as_bool) {
                ac.enabled = x;
            }
            if let Some(x) = a.get("window").and_then(Value::as_usize) {
                ac.window = x;
            }
            if let Some(x) = a.get("min_window").and_then(Value::as_usize) {
                ac.min_window = x;
            }
            if let Some(x) = a.get("gamma_min").and_then(Value::as_usize) {
                ac.gamma_min = x;
            }
            if let Some(x) = a.get("gamma_max").and_then(Value::as_usize) {
                ac.gamma_max = x;
            }
            if let Some(x) = a.get("hysteresis").and_then(Value::as_f64) {
                ac.hysteresis = x;
            }
            if let Some(x) = a.get("cost_ratio").and_then(Value::as_f64) {
                ac.cost_ratio = Some(x);
            }
            b = b.adaptive(ac);
        }
        *self = b.build()?;
        Ok(())
    }
}

/// Builder for [`EngineConfig`].  Every way of constructing an engine
/// config — JSON file, CLI flags, tests — funnels through [`Self::build`],
/// which is the **single** validation point: hard errors for degenerate
/// values, warnings on stderr for keys that are legal but have no effect
/// under the chosen algorithm.  Settings that used to be scattered across
/// call sites ("paths" rewriting, host-verify routing) live here.
#[derive(Clone, Debug)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
    /// Pending `"paths"` override; resolved against the algorithm in
    /// [`Self::build`] so key order in the JSON cannot matter.
    paths: Option<usize>,
}

impl Default for EngineConfigBuilder {
    fn default() -> Self {
        EngineConfig::builder()
    }
}

impl EngineConfigBuilder {
    /// Draft block length (paper gamma).
    pub fn gamma(mut self, gamma: usize) -> Self {
        self.cfg.gamma = gamma;
        self
    }

    /// Verification algorithm.
    pub fn algo(mut self, algo: Algo) -> Self {
        self.cfg.algo = algo;
        self
    }

    /// Path count override for the multi-draft algorithms
    /// ([`Algo::MultiPath`] / [`Algo::Tree`]); warned-and-ignored for
    /// single-draft ones.
    pub fn paths(mut self, k: usize) -> Self {
        self.paths = Some(k);
        self
    }

    /// Drafter variant name.
    pub fn drafter(mut self, name: &str) -> Self {
        self.cfg.drafter = name.to_string();
        self
    }

    /// Per-request generation cap.
    pub fn max_new_tokens(mut self, n: usize) -> Self {
        self.cfg.max_new_tokens = n;
        self
    }

    /// Force host-side verification (cross-checks; greedy needs it).
    pub fn host_verify(mut self, on: bool) -> Self {
        self.cfg.host_verify = on;
        self
    }

    /// RNG seed feeding per-iteration device seeds.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Draft-model inference precision (DESIGN.md §11).
    pub fn draft_precision(mut self, p: Precision) -> Self {
        self.cfg.draft_precision = p;
        self
    }

    /// Adaptive speculation controller knobs (DESIGN.md §15).
    pub fn adaptive(mut self, a: AdaptiveConfig) -> Self {
        self.cfg.adaptive = a;
        self
    }

    /// Native KV cache layout (DESIGN.md §16).
    pub fn kv_layout(mut self, l: KvLayout) -> Self {
        self.cfg.kv_layout = l;
        self
    }

    /// Validate and produce the config.  The one warn-on-stderr point for
    /// engine configuration: degenerate values error, ineffective
    /// combinations warn and are normalised.
    pub fn build(self) -> Result<EngineConfig> {
        let EngineConfigBuilder { mut cfg, paths } = self;
        if cfg.gamma == 0 {
            return Err(anyhow!("gamma must be >= 1"));
        }
        if let Some(k) = paths {
            if k == 0 {
                return Err(anyhow!("paths must be >= 1"));
            }
            match cfg.algo {
                Algo::MultiPath { .. } => cfg.algo = Algo::MultiPath { k },
                Algo::Tree { .. } => cfg.algo = Algo::Tree { k },
                a => eprintln!("specd: config key 'paths' ignored for single-draft algo '{a}'"),
            }
        }
        if cfg.host_verify && matches!(cfg.algo, Algo::MultiPath { .. } | Algo::Tree { .. }) {
            eprintln!(
                "specd: host_verify ignored for '{}'; multi-draft verification runs fused",
                cfg.algo
            );
            cfg.host_verify = false;
        }
        if cfg.max_new_tokens == 0 {
            eprintln!("specd: max_new_tokens is 0; the engine will emit nothing");
        }
        if cfg.adaptive.enabled {
            let a = &mut cfg.adaptive;
            if a.gamma_min == 0 {
                eprintln!("specd: adaptive.gamma_min 0 raised to 1");
                a.gamma_min = 1;
            }
            if a.gamma_max < a.gamma_min {
                eprintln!(
                    "specd: adaptive.gamma_max {} below gamma_min {}; clamping to gamma_min",
                    a.gamma_max, a.gamma_min
                );
                a.gamma_max = a.gamma_min;
            }
            if a.window == 0 {
                eprintln!("specd: adaptive.window 0 raised to 1");
                a.window = 1;
            }
            if a.min_window > a.window {
                eprintln!(
                    "specd: adaptive.min_window {} clamped to window {}",
                    a.min_window, a.window
                );
                a.min_window = a.window;
            }
            if !a.hysteresis.is_finite() || a.hysteresis < 0.0 {
                eprintln!("specd: adaptive.hysteresis {} normalised to 0", a.hysteresis);
                a.hysteresis = 0.0;
            }
            if let Some(r) = a.cost_ratio {
                if !r.is_finite() || r <= 0.0 {
                    eprintln!("specd: adaptive.cost_ratio {r} invalid; measuring online instead");
                    a.cost_ratio = None;
                }
            }
            if cfg.host_verify || !cfg.algo.fused() {
                eprintln!(
                    "specd: adaptive controller requires the fused engine path; \
                     disabling it for host-verify/'{}'",
                    cfg.algo
                );
                cfg.adaptive.enabled = false;
            }
        }
        Ok(cfg)
    }
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    /// Max queued requests before admission control rejects (429).
    pub queue_limit: usize,
    /// Batch-formation wait: how long the batcher waits to fill a batch.
    pub batch_wait_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:8377".into(), queue_limit: 1024, batch_wait_ms: 5 }
    }
}

impl ServerConfig {
    fn apply(&mut self, v: &Value) {
        if let Some(x) = v.get("addr").and_then(Value::as_str) {
            self.addr = x.to_string();
        }
        if let Some(x) = v.get("queue_limit").and_then(Value::as_usize) {
            self.queue_limit = x;
        }
        if let Some(x) = v.get("batch_wait_ms").and_then(Value::as_u64) {
            self.batch_wait_ms = x;
        }
    }
}

/// Serving-tier knobs (DESIGN.md §14): replica fan-out, the paged KV
/// pool and the shared prompt-prefix cache.  Every `0` means "derive
/// from the backend shapes at spawn" so partial configs stay valid
/// across model-geometry changes.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Engine replicas, each with its own worker thread and KV slots.
    pub replicas: usize,
    /// KV positions per pool page.
    pub page_size: usize,
    /// Total pool pages; 0 = auto (fund every replica's full slot table
    /// plus prefix-cache headroom).
    pub kv_pages: usize,
    /// Per-replica admission token budget (prompt + generation tokens
    /// outstanding); 0 = auto (a few batches' worth).
    pub token_budget: usize,
    /// Prompt-prefix KV cache on/off.
    pub prefix_cache: bool,
    /// Shortest prefix worth caching; 0 = auto (one page).
    pub min_prefix_len: usize,
    /// Debug/test override: route everything to this replica instead of
    /// least-outstanding-tokens placement.
    pub pinned_replica: Option<usize>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            replicas: 2,
            page_size: 16,
            kv_pages: 0,
            token_budget: 0,
            prefix_cache: true,
            min_prefix_len: 0,
            pinned_replica: None,
        }
    }
}

impl RouterConfig {
    /// The shape [`crate::coordinator::Coordinator`] runs the router in
    /// to preserve its historical single-engine semantics: one replica,
    /// no prefix cache, a pool that always funds the full slot table and
    /// a token budget that never sheds (its `AdmissionGate` already
    /// bounds in-flight requests).
    pub fn single_engine() -> Self {
        RouterConfig {
            replicas: 1,
            prefix_cache: false,
            token_budget: usize::MAX / 4,
            ..RouterConfig::default()
        }
    }

    fn apply(&mut self, v: &Value) {
        if let Some(x) = v.get("replicas").and_then(Value::as_usize) {
            self.replicas = x.max(1);
        }
        if let Some(x) = v.get("page_size").and_then(Value::as_usize) {
            self.page_size = x.max(1);
        }
        if let Some(x) = v.get("kv_pages").and_then(Value::as_usize) {
            self.kv_pages = x;
        }
        if let Some(x) = v.get("token_budget").and_then(Value::as_usize) {
            self.token_budget = x;
        }
        if let Some(x) = v.get("prefix_cache").and_then(Value::as_bool) {
            self.prefix_cache = x;
        }
        if let Some(x) = v.get("min_prefix_len").and_then(Value::as_usize) {
            self.min_prefix_len = x;
        }
        if let Some(x) = v.get("pinned_replica").and_then(Value::as_usize) {
            self.pinned_replica = Some(x);
        }
    }
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Prompts per dataset per run (paper: 1000; scaled default).
    pub prompts_per_dataset: usize,
    /// Seeds averaged in each table cell (paper: 3).
    pub seeds: Vec<u64>,
    /// Generation cap per prompt.
    pub max_new_tokens: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig { prompts_per_dataset: 64, seeds: vec![0, 1, 2], max_new_tokens: 48 }
    }
}

impl ExperimentConfig {
    fn apply(&mut self, v: &Value) {
        if let Some(x) = v.get("prompts_per_dataset").and_then(Value::as_usize) {
            self.prompts_per_dataset = x;
        }
        if let Some(arr) = v.get("seeds").and_then(Value::as_arr) {
            self.seeds = arr.iter().filter_map(Value::as_u64).collect();
        }
        if let Some(x) = v.get("max_new_tokens").and_then(Value::as_usize) {
            self.max_new_tokens = x;
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Config {
    /// Artifact bundle directory (manifest.json etc).
    pub artifacts: Option<PathBuf>,
    pub engine: EngineConfig,
    pub server: ServerConfig,
    pub router: RouterConfig,
    pub experiments: ExperimentConfig,
}

impl Config {
    pub fn parse(raw: &str) -> Result<Self> {
        let v = crate::util::json::parse(raw).context("parsing config JSON")?;
        let mut cfg = Config::default();
        if let Some(a) = v.get("artifacts").and_then(Value::as_str) {
            cfg.artifacts = Some(PathBuf::from(a));
        }
        if let Some(e) = v.get("engine") {
            cfg.engine.apply(e)?;
        }
        if let Some(s) = v.get("server") {
            cfg.server.apply(s);
        }
        if let Some(r) = v.get("router") {
            cfg.router.apply(r);
        }
        if let Some(x) = v.get("experiments") {
            cfg.experiments.apply(x);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let raw = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&raw)
    }

    /// Resolve the artifacts directory: explicit config > $SPECD_ARTIFACTS >
    /// ./artifacts.
    pub fn artifacts_dir(&self) -> PathBuf {
        if let Some(p) = &self.artifacts {
            return p.clone();
        }
        if let Ok(p) = std::env::var("SPECD_ARTIFACTS") {
            return PathBuf::from(p);
        }
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert_eq!(c.engine.gamma, 8);
        assert_eq!(c.engine.algo, Algo::Block);
        assert!(!c.engine.effective_host_verify());
        let mut g = c.engine.clone();
        g.algo = Algo::Greedy;
        assert!(g.effective_host_verify());
    }

    #[test]
    fn partial_json_fills_defaults() {
        let c = Config::parse(r#"{"engine": {"gamma": 4, "algo": "token"}}"#).unwrap();
        assert_eq!(c.engine.gamma, 4);
        assert_eq!(c.engine.algo, Algo::Token);
        assert_eq!(c.engine.drafter, "xxs");
        assert_eq!(c.experiments.seeds, vec![0, 1, 2]);
    }

    #[test]
    fn full_sections_parse() {
        let c = Config::parse(
            r#"{"artifacts": "/tmp/a",
                "server": {"addr": "0.0.0.0:9000", "queue_limit": 8},
                "experiments": {"prompts_per_dataset": 16, "seeds": [5, 6]}}"#,
        )
        .unwrap();
        assert_eq!(c.artifacts_dir(), PathBuf::from("/tmp/a"));
        assert_eq!(c.server.addr, "0.0.0.0:9000");
        assert_eq!(c.experiments.seeds, vec![5, 6]);
    }

    #[test]
    fn router_section_parses_and_defaults() {
        let c = Config::default();
        assert_eq!(c.router.replicas, 2);
        assert!(c.router.prefix_cache);
        assert_eq!(c.router.pinned_replica, None);
        let c = Config::parse(
            r#"{"router": {"replicas": 4, "page_size": 8, "kv_pages": 64,
                "token_budget": 2048, "prefix_cache": false,
                "min_prefix_len": 24, "pinned_replica": 1}}"#,
        )
        .unwrap();
        assert_eq!(c.router.replicas, 4);
        assert_eq!(c.router.page_size, 8);
        assert_eq!(c.router.kv_pages, 64);
        assert_eq!(c.router.token_budget, 2048);
        assert!(!c.router.prefix_cache);
        assert_eq!(c.router.min_prefix_len, 24);
        assert_eq!(c.router.pinned_replica, Some(1));
        // degenerate values clamp rather than error (serving keeps running)
        let c = Config::parse(r#"{"router": {"replicas": 0, "page_size": 0}}"#).unwrap();
        assert_eq!(c.router.replicas, 1);
        assert_eq!(c.router.page_size, 1);
        // the coordinator's single-engine shape
        let s = RouterConfig::single_engine();
        assert_eq!(s.replicas, 1);
        assert!(!s.prefix_cache);
    }

    #[test]
    fn bad_algo_rejected() {
        assert!(Config::parse(r#"{"engine": {"algo": "bogus"}}"#).is_err());
    }

    #[test]
    fn draft_precision_parses_and_rejects_garbage() {
        let c = Config::parse(r#"{"engine": {"draft_precision": "fp32"}}"#).unwrap();
        assert_eq!(c.engine.draft_precision, Precision::Fp32);
        let c = Config::parse(r#"{"engine": {"draft_precision": "int8"}}"#).unwrap();
        assert_eq!(c.engine.draft_precision, Precision::Int8);
        assert!(Config::parse(r#"{"engine": {"draft_precision": "fp64"}}"#).is_err());
    }

    #[test]
    fn kv_layout_parses_and_rejects_garbage() {
        // No env override in the test environment: the default is paged.
        let c = Config::parse(r#"{"engine": {"kv_layout": "contig"}}"#).unwrap();
        assert_eq!(c.engine.kv_layout, KvLayout::Contig);
        let c = Config::parse(r#"{"engine": {"kv_layout": "paged"}}"#).unwrap();
        assert_eq!(c.engine.kv_layout, KvLayout::Paged);
        assert!(Config::parse(r#"{"engine": {"kv_layout": "sparse"}}"#).is_err());
        // The builder funnel carries it like every other engine knob.
        let cfg = EngineConfig::builder().kv_layout(KvLayout::Contig).build().unwrap();
        assert_eq!(cfg.kv_layout, KvLayout::Contig);
    }

    #[test]
    fn multipath_algo_and_paths() {
        let c = Config::parse(r#"{"engine": {"algo": "multipath"}}"#).unwrap();
        assert_eq!(c.engine.algo, Algo::MultiPath { k: 2 });
        let c = Config::parse(r#"{"engine": {"algo": "multipath", "paths": 4}}"#).unwrap();
        assert_eq!(c.engine.algo, Algo::MultiPath { k: 4 });
        let c = Config::parse(r#"{"engine": {"algo": "multipath:3"}}"#).unwrap();
        assert_eq!(c.engine.algo, Algo::MultiPath { k: 3 });
        // paths is ignored for single-draft algorithms...
        let c = Config::parse(r#"{"engine": {"algo": "block", "paths": 4}}"#).unwrap();
        assert_eq!(c.engine.algo, Algo::Block);
        // ...and rejected when degenerate for multipath.
        assert!(Config::parse(r#"{"engine": {"algo": "multipath", "paths": 0}}"#).is_err());
        // multipath stays on the fused engine path.
        let c = Config::parse(r#"{"engine": {"algo": "multipath"}}"#).unwrap();
        assert!(!c.engine.effective_host_verify());
    }

    #[test]
    fn tree_algo_and_paths() {
        let c = Config::parse(r#"{"engine": {"algo": "tree"}}"#).unwrap();
        assert_eq!(c.engine.algo, Algo::Tree { k: 2 });
        let c = Config::parse(r#"{"engine": {"algo": "tree:4"}}"#).unwrap();
        assert_eq!(c.engine.algo, Algo::Tree { k: 4 });
        // "paths" overrides the tree width exactly as it does multipath's.
        let c = Config::parse(r#"{"engine": {"algo": "tree", "paths": 3}}"#).unwrap();
        assert_eq!(c.engine.algo, Algo::Tree { k: 3 });
        assert!(Config::parse(r#"{"engine": {"algo": "tree", "paths": 0}}"#).is_err());
        // Tree runs on the fused engine path.
        assert!(!c.engine.effective_host_verify());
    }

    #[test]
    fn adaptive_defaults_off_and_parses() {
        let c = Config::default();
        assert!(!c.engine.adaptive.enabled, "adaptive must default off (bit-identity)");
        assert_eq!(c.engine.adaptive.window, 32);
        assert_eq!(c.engine.adaptive.cost_ratio, None);
        let c = Config::parse(
            r#"{"engine": {"adaptive": {"enabled": true, "window": 8, "min_window": 2,
                "gamma_min": 1, "gamma_max": 6, "hysteresis": 0.05, "cost_ratio": 0.25}}}"#,
        )
        .unwrap();
        let a = &c.engine.adaptive;
        assert!(a.enabled);
        assert_eq!((a.window, a.min_window, a.gamma_min, a.gamma_max), (8, 2, 1, 6));
        assert_eq!(a.cost_ratio, Some(0.25));
        assert!((a.hysteresis - 0.05).abs() < 1e-12);
    }

    #[test]
    fn adaptive_degenerate_values_normalise_in_build() {
        let c = Config::parse(
            r#"{"engine": {"adaptive": {"enabled": true, "window": 0, "min_window": 9,
                "gamma_min": 0, "gamma_max": 0, "hysteresis": -1.0, "cost_ratio": -2.0}}}"#,
        )
        .unwrap();
        let a = &c.engine.adaptive;
        assert!(a.enabled);
        assert_eq!(a.gamma_min, 1);
        assert_eq!(a.gamma_max, 1);
        assert_eq!(a.window, 1);
        assert_eq!(a.min_window, 1);
        assert_eq!(a.hysteresis, 0.0);
        assert_eq!(a.cost_ratio, None);
    }

    #[test]
    fn adaptive_disabled_off_the_fused_path() {
        // Host-verify and greedy lack the ragged fused iteration the
        // controller drives; the builder warns and turns it off.
        let a = AdaptiveConfig { enabled: true, ..AdaptiveConfig::default() };
        let cfg = EngineConfig::builder().adaptive(a.clone()).host_verify(true).build().unwrap();
        assert!(!cfg.adaptive.enabled);
        let cfg = EngineConfig::builder().adaptive(a).algo(Algo::Greedy).build().unwrap();
        assert!(!cfg.adaptive.enabled);
    }

    #[test]
    fn adaptive_env_flag_parses_strictly() {
        for s in ["1", "on", "ON", "true", "yes"] {
            assert_eq!(adaptive_flag(s), Some(true), "{s}");
        }
        for s in ["", "0", "off", "Off", "false", "no"] {
            assert_eq!(adaptive_flag(s), Some(false), "{s:?}");
        }
        // Unknown values are None: the env reader warns and falls back.
        assert_eq!(adaptive_flag("fast"), None);
        assert_eq!(adaptive_flag("2"), None);
    }

    #[test]
    fn builder_is_the_single_validation_point() {
        let cfg = EngineConfig::builder()
            .gamma(4)
            .algo(Algo::Tree { k: 2 })
            .paths(4)
            .drafter("xxxs")
            .max_new_tokens(16)
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(cfg.gamma, 4);
        assert_eq!(cfg.algo, Algo::Tree { k: 4 });
        assert_eq!(cfg.drafter, "xxxs");
        assert_eq!(cfg.seed, 7);
        // Degenerate values are hard errors...
        assert!(EngineConfig::builder().gamma(0).build().is_err());
        assert!(EngineConfig::builder().paths(0).build().is_err());
        // ...ineffective combinations warn (stderr) and normalise: the
        // host-verify flag cannot route a multi-draft algo off the fused
        // engine.
        let cfg = EngineConfig::builder()
            .algo(Algo::MultiPath { k: 2 })
            .host_verify(true)
            .build()
            .unwrap();
        assert!(!cfg.host_verify);
        assert!(!cfg.effective_host_verify());
        // JSON "gamma": 0 now funnels through the same check.
        assert!(Config::parse(r#"{"engine": {"gamma": 0}}"#).is_err());
    }
}
