//! Offline API stub of the `xla` crate (PJRT bindings).
//!
//! The real crate needs network access and the native XLA/PJRT toolchain,
//! neither of which exists in the build image.  This stub reproduces the
//! exact API surface `specd::runtime::pjrt` uses so that
//! `cargo check --features pjrt` type-checks offline:
//!
//! * [`Literal`] is fully functional — a host tensor container (f32/i32
//!   data + dims + tuple nesting), so `runtime::literal` works for real.
//! * The PJRT types ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`PjRtBuffer`], [`HloModuleProto`], [`XlaComputation`]) carry no
//!   backing implementation: constructors and executions return
//!   [`Error::Unimplemented`] at runtime.
//!
//! To run the PJRT path for real, replace this path dependency in
//! `rust/Cargo.toml` with the actual `xla` crate; no `specd` source changes
//! are required (the surface below is signature-compatible).

use std::fmt;

/// Stub error type (the real crate's error also implements
/// `std::error::Error`, which `?`-conversion in specd relies on).
#[derive(Debug)]
pub enum Error {
    Unimplemented(&'static str),
    Shape(String),
    Type(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unimplemented(what) => write!(
                f,
                "{what}: built against the vendored xla stub — replace \
                 rust/vendor/xla with the real xla crate to use the PJRT backend"
            ),
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
            Error::Type(msg) => write!(f, "element type error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------------
// Literal: a working host tensor container.
// ---------------------------------------------------------------------------

/// Storage for [`Literal`] payloads.  Public only because the
/// [`ArrayElement`] trait names it in its methods; not part of the real
/// crate's API surface.
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host literal: typed flat data plus dimensions (or a tuple of literals).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types storable in a [`Literal`].
pub trait ArrayElement: Copy + Sized {
    fn wrap(values: Vec<Self>) -> Data;
    fn extract(data: &Data) -> Option<Vec<Self>>;
}

impl ArrayElement for f32 {
    fn wrap(values: Vec<Self>) -> Data {
        Data::F32(values)
    }

    fn extract(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl ArrayElement for i32 {
    fn wrap(values: Vec<Self>) -> Data {
        Data::I32(values)
    }

    fn extract(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: ArrayElement>(values: &[T]) -> Literal {
        let n = values.len() as i64;
        Literal { data: T::wrap(values.to_vec()), dims: vec![n] }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match; `&[]`
    /// produces a rank-0 scalar from a 1-element literal).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error::Shape("cannot reshape a tuple literal".into()));
        }
        if want.max(1) != have {
            return Err(Error::Shape(format!("reshape {have} elements to {dims:?}")));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Read the flat data back as a typed vector.
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        T::extract(&self.data)
            .ok_or_else(|| Error::Type("literal holds a different element type".into()))
    }

    /// Decompose a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error::Shape("literal is not a tuple".into())),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// PJRT surface: signature-compatible, unimplemented at runtime.
// ---------------------------------------------------------------------------

/// Stub PJRT client.
pub struct PjRtClient {
    _priv: (),
}

/// Stub PJRT device handle.
pub struct PjRtDevice {
    _priv: (),
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _priv: (),
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _priv: (),
}

/// Stub XLA computation.
pub struct XlaComputation {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unimplemented("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unimplemented("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unimplemented("PjRtClient::buffer_from_host_literal"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented("PjRtLoadedExecutable::execute_b"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unimplemented("PjRtBuffer::to_literal_sync"))
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unimplemented("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let m = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.dims(), &[2, 2]);
        assert!(lit.reshape(&[3]).is_err());
        let scalar = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(scalar.to_vec::<i32>().unwrap(), vec![7]);
        assert!(scalar.to_vec::<f32>().is_err());
    }

    #[test]
    fn pjrt_surface_reports_stub() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
    }
}
