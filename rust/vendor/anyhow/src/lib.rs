//! Vendored, offline subset of the `anyhow` crate API used by `specd`.
//!
//! The build image has no network access, so instead of the real crate we
//! ship this drop-in shim covering exactly the surface the codebase uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros and the
//! [`Context`] extension trait.  Semantics match `anyhow` where it matters:
//! `{e}` prints the outermost message, `{e:#}` prints the whole context
//! chain separated by `": "`, and any `std::error::Error` converts via `?`.
//!
//! If the real `anyhow` ever becomes available, deleting this crate and
//! switching the path dependency to a registry dependency is a no-op for
//! the rest of the workspace.

use std::fmt;

/// An error chain: `chain[0]` is the outermost (most recently attached)
/// message, later entries are the causes it wraps.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what the `anyhow!` macro calls).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message (what [`Context`] calls).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost to innermost cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// keeps the blanket `From` below coherent with core's reflexive `From`,
// exactly as the real anyhow does.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(cause) = src {
            chain.push(cause.to_string());
            src = cause.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: the full chain, outermost first.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

/// `anyhow::Result<T>` with the usual default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, converting the error into [`Error`] along the way.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading weights");
        assert_eq!(format!("{e}"), "reading weights");
        assert_eq!(format!("{e:#}"), "reading weights: gone");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<()> {
            let flag = true;
            if flag {
                bail!("flag was {flag}");
            }
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "flag was true");
        fn through() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(through().is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let r: Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: gone");
    }
}
