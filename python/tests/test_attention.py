"""L1: Pallas cached-attention kernel vs the numpy oracle (hypothesis sweep
over query/cache sizes, heads and head dims)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import attention, ref


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([1, 4, 9]),
    l=st.sampled_from([16, 48]),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 10_000),
)
def test_cached_attention_matches_oracle(t, l, h, d, seed):
    rng = np.random.default_rng(seed)
    b = 2
    q = rng.normal(size=(b, t, h, d)).astype(np.float32)
    k = rng.normal(size=(b, l, h, d)).astype(np.float32)
    v = rng.normal(size=(b, l, h, d)).astype(np.float32)
    start = rng.integers(1, l - t, size=b).astype(np.int32)
    qpos = start[:, None] + np.arange(t, dtype=np.int32)[None]
    out = attention.cached_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        jnp.asarray(qpos), jnp.asarray(start + t),
    )
    for bi in range(b):
        kpos = np.arange(l)
        mask = kpos[None, :] <= qpos[bi][:, None]
        want = ref.reference_attention(q[bi], k[bi], v[bi], mask)
        np.testing.assert_allclose(np.array(out[bi]), want, rtol=2e-3, atol=2e-4)


def test_causal_mask_blocks_future():
    """A query at position p must ignore cache rows > p entirely."""
    b, t, l, h, d = 1, 1, 8, 1, 4
    rng = np.random.default_rng(0)
    q = rng.normal(size=(b, t, h, d)).astype(np.float32)
    k = rng.normal(size=(b, l, h, d)).astype(np.float32)
    v = rng.normal(size=(b, l, h, d)).astype(np.float32)
    qpos = np.array([[3]], np.int32)
    out1 = attention.cached_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        jnp.asarray(qpos), jnp.asarray([4], dtype=np.int32),
    )
    # Scribble over the masked region; output must be unchanged.
    k2, v2 = k.copy(), v.copy()
    k2[:, 4:] = 99.0
    v2[:, 4:] = -99.0
    out2 = attention.cached_attention(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2),
        jnp.asarray(qpos), jnp.asarray([4], dtype=np.int32),
    )
    np.testing.assert_allclose(np.array(out1), np.array(out2), rtol=1e-6)
