"""L2 correctness: the transformer substrate and the fused SpecDec programs.

Uses a tiny config so tests run in seconds; the contracts checked here are
shape- and semantics-level (incremental == dense forward, Pallas attention
== jnp attention, spec_iter bookkeeping) and hold for any size.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, model

TINY_T = common.ModelConfig("tiny_t", n_layers=2, d_model=32, n_heads=2, max_len=32)
TINY_D = common.ModelConfig("tiny_d", n_layers=1, d_model=16, n_heads=2, max_len=32)


@pytest.fixture(scope="module")
def tiny():
    pt = model.init_params(TINY_T, jax.random.PRNGKey(0))
    pd = model.init_params(TINY_D, jax.random.PRNGKey(1))
    return pt, pd


def test_incremental_equals_dense(tiny):
    pt, _ = tiny
    B, L = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 3, common.VOCAB_SIZE)
    dense = np.exp(np.array(model.forward_train(TINY_T, pt, toks)))
    kv = model.prefill(TINY_T, pt, toks, jnp.full((B,), 6, jnp.int32))
    for p in range(5, 12):
        probs, kv = model.forward_block(
            TINY_T, pt, kv, toks[:, p][:, None], jnp.full((B,), p, jnp.int32),
            use_pallas=False,
        )
        np.testing.assert_allclose(np.array(probs[:, 0]), dense[:, p], rtol=2e-3, atol=2e-5)


def test_pallas_attention_equals_jnp(tiny):
    pt, _ = tiny
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 32), 3, common.VOCAB_SIZE)
    length = jnp.full((B,), 7, jnp.int32)
    kv = model.prefill(TINY_T, pt, toks, length)
    drafts = toks[:, 8:12]
    ps_pl, _ = model.target_score(TINY_T, pt, kv, toks, length, drafts, use_pallas=True)
    ps_jn, _ = model.target_score(TINY_T, pt, kv, toks, length, drafts, use_pallas=False)
    np.testing.assert_allclose(np.array(ps_pl), np.array(ps_jn), rtol=2e-3, atol=2e-5)


def test_probs_are_distributions(tiny):
    pt, _ = tiny
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 32), 3, common.VOCAB_SIZE)
    kv = model.init_kv(TINY_T, 2)
    probs, _ = model.forward_block(
        TINY_T, pt, kv, toks[:, :5], jnp.zeros((2,), jnp.int32), use_pallas=False
    )
    s = np.array(probs.sum(-1))
    np.testing.assert_allclose(s, np.ones_like(s), rtol=1e-4)
    assert np.all(np.array(probs) >= 0)


def test_draft_scan_qs_match_single_steps(tiny):
    """The scan's qs rows must equal step-by-step decoding distributions."""
    _, pd = tiny
    B, L, g = 2, 32, 4
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, L), 3, common.VOCAB_SIZE)
    length = jnp.full((B,), 6, jnp.int32)
    kv0 = model.prefill(TINY_D, pd, toks, length)
    key = jax.random.PRNGKey(9)
    drafts, qs, _ = model.draft_scan(TINY_D, pd, kv0, toks, length, g, key)
    assert drafts.shape == (B, g)
    assert qs.shape == (B, g, common.VOCAB_SIZE)
    # Replay: feed the pending token then the sampled drafts manually.
    kv = model.prefill(TINY_D, pd, toks, length)
    cur = toks[jnp.arange(B), length - 1][:, None]
    for j in range(g):
        probs, kv = model.forward_block(
            TINY_D, pd, kv, cur, length - 1 + j, use_pallas=False
        )
        np.testing.assert_allclose(
            np.array(probs[:, 0]), np.array(qs[:, j]), rtol=2e-3, atol=1e-5
        )
        cur = drafts[:, j][:, None]


def test_spec_iter_bookkeeping(tiny):
    pt, pd = tiny
    B, L, g = 2, 32, 4
    toks = jnp.full((B, L), common.PAD_ID, jnp.int32)
    prompt = jnp.array(
        [[common.BOS_ID, 3, 20, 21], [common.BOS_ID, 4, 30, 31]], jnp.int32
    )
    toks = toks.at[:, :4].set(prompt)
    length = jnp.full((B,), 4, jnp.int32)
    kvt = model.prefill(TINY_T, pt, toks, length)
    kvd = model.prefill(TINY_D, pd, toks, length)
    toks2, len2, _, _, tau, emitted, done = model.spec_iter(
        TINY_T, TINY_D, pt, pd, toks, length, kvt, kvd, 7,
        gamma=g, algo="block", max_len=L,
    )
    tau = np.array(tau)
    len2 = np.array(len2)
    emitted = np.array(emitted)
    toks2 = np.array(toks2)
    assert np.all(len2 == 4 + tau + 1)
    for b in range(B):
        # emitted tokens were written into the sequence buffer
        for j in range(tau[b] + 1):
            assert toks2[b, 4 + j] == emitted[b, j]
        # prompt untouched
        assert np.array_equal(toks2[b, :4], np.array(prompt[b]))
    assert np.array(done).dtype == np.int32


def test_spec_iter_token_vs_block_same_drafts(tiny):
    """With the same seed the two algorithms see identical drafts; block
    must accept at least as many tokens in expectation."""
    pt, pd = tiny
    B, L, g = 2, 32, 4
    toks = jnp.full((B, L), common.PAD_ID, jnp.int32)
    toks = toks.at[:, :3].set(jnp.array([[1, 3, 20], [1, 4, 30]], jnp.int32))
    length = jnp.full((B,), 3, jnp.int32)
    kvt = model.prefill(TINY_T, pt, toks, length)
    kvd = model.prefill(TINY_D, pd, toks, length)
    tot = {"token": 0, "block": 0}
    for algo in tot:
        acc = 0
        for seed in range(40):
            *_, tau, _, _ = model.spec_iter(
                TINY_T, TINY_D, pt, pd, toks, length, kvt, kvd, seed,
                gamma=g, algo=algo, max_len=L,
            )
            acc += int(np.array(tau).sum())
        tot[algo] = acc
    assert tot["block"] >= tot["token"] * 0.95, tot


def test_baseline_step(tiny):
    pt, _ = tiny
    B, L = 2, 32
    toks = jnp.full((B, L), common.PAD_ID, jnp.int32)
    toks = toks.at[:, :3].set(jnp.array([[1, 3, 20], [1, 4, 30]], jnp.int32))
    length = jnp.full((B,), 3, jnp.int32)
    kv = model.prefill(TINY_T, pt, toks, length)
    toks2, len2, kv, nxt, done = model.baseline_step(
        TINY_T, pt, toks, length, kv, 5, max_len=L
    )
    assert np.all(np.array(len2) == 4)
    assert np.all(np.array(nxt) >= 0)
    assert np.all(np.array(nxt) < common.VOCAB_SIZE)
    assert np.array(toks2)[0, 3] == np.array(nxt)[0]
