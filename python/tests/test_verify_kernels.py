"""L1 correctness: the Pallas verification kernels against the numpy oracle.

The kernels take explicit uniforms, so agreement is draw-for-draw: same
(ps, qs, drafts, etas, u) must give the same (tau, emitted).  Hypothesis
sweeps shapes, concentrations and adversarial cases (identical models,
deterministic rows).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref, verify
from tests.conftest import random_probs


def run_both(ps, qs, drafts, etas, us, algo):
    kfn = verify.VERIFIERS[algo]
    em, tau = kfn(jnp.asarray(ps), jnp.asarray(qs), jnp.asarray(drafts), jnp.asarray(etas), jnp.asarray(us))
    rfn = {"token": ref.token_verify, "block": ref.block_verify}[algo]
    out = []
    for b in range(ps.shape[0]):
        rt, re = rfn(ps[b], qs[b], drafts[b], etas[b], us[b])
        out.append((rt, re, int(tau[b]), [int(x) for x in np.array(em[b][: rt + 1])]))
    return out


@settings(max_examples=25, deadline=None)
@given(
    gamma=st.sampled_from([1, 2, 4, 8]),
    vocab=st.sampled_from([4, 16, 64]),
    conc=st.sampled_from([0.3, 1.0, 4.0]),
    seed=st.integers(0, 10_000),
    algo=st.sampled_from(["token", "block"]),
)
def test_kernel_matches_oracle(gamma, vocab, conc, seed, algo):
    rng = np.random.default_rng(seed)
    B = 2
    ps = random_probs(rng, B, gamma + 1, vocab, conc=conc)
    qs = random_probs(rng, B, gamma, vocab, conc=conc)
    drafts = np.stack(
        [[rng.choice(vocab, p=qs[b, i]) for i in range(gamma)] for b in range(B)]
    ).astype(np.int32)
    etas = rng.random((B, gamma)).astype(np.float32)
    us = rng.random(B).astype(np.float32)
    for rt, re, kt, ke in run_both(ps, qs, drafts, etas, us, algo):
        assert rt == kt
        assert re == ke


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), gamma=st.sampled_from([2, 6]))
def test_identical_models_accept_everything(seed, gamma):
    """ps == qs ⇒ every draft accepted, bonus from M_b (both algorithms)."""
    rng = np.random.default_rng(seed)
    vocab = 16
    rows = random_probs(rng, gamma + 1, vocab)
    ps = rows[None]
    qs = rows[None, :gamma]
    drafts = np.array([[rng.choice(vocab, p=qs[0, i]) for i in range(gamma)]], np.int32)
    etas = rng.random((1, gamma)).astype(np.float32)
    us = rng.random(1).astype(np.float32)
    for algo in ["token", "block"]:
        em, tau = verify.VERIFIERS[algo](
            jnp.asarray(ps), jnp.asarray(qs), jnp.asarray(drafts),
            jnp.asarray(etas), jnp.asarray(us),
        )
        assert int(tau[0]) == gamma, algo
        assert np.array_equal(np.array(em[0][:gamma]), drafts[0]), algo


def test_block_chain_matches_oracle_values():
    rng = np.random.default_rng(3)
    gamma, vocab = 6, 32
    ps = random_probs(rng, 1, gamma + 1, vocab)
    qs = random_probs(rng, 1, gamma, vocab)
    drafts = np.array([[rng.choice(vocab, p=qs[0, i]) for i in range(gamma)]], np.int32)
    etas = rng.random((1, gamma)).astype(np.float32)
    us = rng.random(1).astype(np.float32)
    _, _, p, h = verify.block_verify(
        jnp.asarray(ps), jnp.asarray(qs), jnp.asarray(drafts),
        jnp.asarray(etas), jnp.asarray(us), debug=True,
    )
    rp, rh = ref.block_chain(ps[0], qs[0], drafts[0])
    np.testing.assert_allclose(np.array(p[0]), rp, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.array(h[0]), rh, rtol=1e-4, atol=1e-6)
    # chain is in [0, 1]
    assert np.all(np.array(p[0]) >= 0) and np.all(np.array(p[0]) <= 1 + 1e-6)


def test_gamma1_token_equals_block():
    """The paper notes the algorithms coincide at gamma = 1."""
    rng = np.random.default_rng(11)
    for _ in range(50):
        ps = random_probs(rng, 1, 2, 8)
        qs = random_probs(rng, 1, 1, 8)
        drafts = np.array([[rng.choice(8, p=qs[0, 0])]], np.int32)
        etas = rng.random((1, 1)).astype(np.float32)
        us = rng.random(1).astype(np.float32)
        a = run_both(ps, qs, drafts, etas, us, "token")[0]
        b = run_both(ps, qs, drafts, etas, us, "block")[0]
        assert a == b


def test_block_never_worse_than_token_in_tau_expectation():
    """Theorem 2 at kernel level: E[tau_block] >= E[tau_token] (paired MC)."""
    rng = np.random.default_rng(7)
    gamma, vocab, B = 6, 16, 4
    tot_t = tot_b = 0
    for _ in range(60):
        ps = random_probs(rng, B, gamma + 1, vocab)
        qs = random_probs(rng, B, gamma, vocab)
        drafts = np.stack(
            [[rng.choice(vocab, p=qs[b, i]) for i in range(gamma)] for b in range(B)]
        ).astype(np.int32)
        etas = rng.random((B, gamma)).astype(np.float32)
        us = rng.random(B).astype(np.float32)
        _, tau_t = verify.token_verify(
            jnp.asarray(ps), jnp.asarray(qs), jnp.asarray(drafts),
            jnp.asarray(etas), jnp.asarray(us))
        _, tau_b = verify.block_verify(
            jnp.asarray(ps), jnp.asarray(qs), jnp.asarray(drafts),
            jnp.asarray(etas), jnp.asarray(us))
        tot_t += int(np.sum(np.array(tau_t)))
        tot_b += int(np.sum(np.array(tau_b)))
    # statistical: allow tiny slack
    assert tot_b >= tot_t * 0.98, (tot_t, tot_b)


def test_greedy_oracle_layer_bookkeeping():
    """Algorithm 5: a rejection opens a window layer of the right length
    with a positive joint ratio; full acceptance leaves no layers."""
    rng = np.random.default_rng(5)
    gamma, vocab = 4, 8
    ps = random_probs(rng, gamma + 1, vocab)
    qs = random_probs(rng, gamma, vocab)
    drafts = np.array([rng.choice(vocab, p=qs[i]) for i in range(gamma)])
    # Force rejection of everything: etas = 1.0 (h < 1 almost surely)
    etas = np.ones(gamma) - 1e-9
    tau, emitted, layers = ref.greedy_verify(ps, qs, drafts, etas, 0.5)
    assert len(emitted) == tau + 1
    if tau < gamma - 1:
        assert len(layers) == 1
        rem, ratio = layers[0]
        assert rem == gamma - tau - 1
        assert ratio > 0
    # identical models + tiny etas: accept everything, no window
    rows = random_probs(rng, gamma + 1, vocab)
    drafts2 = np.array([rng.choice(vocab, p=rows[i]) for i in range(gamma)])
    tau2, _, layers2 = ref.greedy_verify(
        rows, rows[:gamma], drafts2, np.zeros(gamma) + 1e-9, 0.5
    )
    assert tau2 == gamma
    assert layers2 == []
