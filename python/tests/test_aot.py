"""AOT pipeline: weight flattening, golden-vector generation, and (when the
bundle has been built) manifest/bundle integrity."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, common, model
from compile.kernels import ref


def test_flatten_params_order_is_deterministic():
    cfg = common.ModelConfig("t", 1, 16, 2, max_len=8)
    p1 = model.init_params(cfg, jax.random.PRNGKey(0))
    p2 = model.init_params(cfg, jax.random.PRNGKey(0))
    n1, _ = aot.flatten_params(p1)
    n2, _ = aot.flatten_params(p2)
    assert [n for n, _ in n1] == [n for n, _ in n2]
    # embed must come first (dict order is sorted)
    assert "embed" in n1[0][0]


def test_write_weights_offsets(tmp_path):
    cfg = common.ModelConfig("t", 1, 16, 2, max_len=8)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    path = tmp_path / "w.bin"
    entries = aot.write_weights(str(path), params)
    data = np.fromfile(path, dtype="<f4")
    total = sum(int(np.prod(e["shape"]) if e["shape"] else 1) for e in entries)
    assert len(data) == total
    # spot-check an entry round-trips
    named, _ = aot.flatten_params(params)
    for (name, arr), e in zip(named, entries):
        assert name == e["name"]
        n = int(np.prod(e["shape"])) if e["shape"] else 1
        np.testing.assert_array_equal(
            data[e["offset"] : e["offset"] + n], arr.flatten()
        )


def test_golden_vectors_selfcheck(tmp_path):
    aot.export_golden(str(tmp_path), n_cases=8)
    cases = json.load(open(tmp_path / "golden_verify.json"))
    assert len(cases) == 8
    for c in cases:
        g, v = c["gamma"], c["vocab"]
        ps = np.array(c["ps"]).reshape(g + 1, v)
        qs = np.array(c["qs"]).reshape(g, v)
        tau, emitted = ref.block_verify(ps, qs, c["drafts"], c["etas"], c["u"])
        assert tau == c["block"]["tau"]
        assert emitted == c["block"]["emitted"]
        assert len(emitted) == tau + 1


# ---------------------------------------------------------------------------
# Bundle integrity (needs `make artifacts`)
# ---------------------------------------------------------------------------


def test_manifest_structure(artifacts_dir):
    m = json.load(open(os.path.join(artifacts_dir, "manifest.json")))
    assert m["version"] == 1
    assert set(m["drafters"]) == {"xxs", "xxxs"}
    assert sorted(m["gammas"]) == [4, 6, 8]
    for name in ["target", "xxs", "xxxs"]:
        meta = m["models"][name]
        wpath = os.path.join(artifacts_dir, meta["weights_file"])
        n_floats = os.path.getsize(wpath) // 4
        declared = sum(
            int(np.prod(w["shape"])) if w["shape"] else 1 for w in meta["weights"]
        )
        assert n_floats == declared, name
    # every program file exists and declares matching arg counts
    for pname, prog in m["programs"].items():
        path = os.path.join(artifacts_dir, prog["file"])
        assert os.path.exists(path), pname
        text = open(path).read(200_000)
        assert "ENTRY" in text
    # the full fused grid exists
    for algo in ["token", "block"]:
        for drafter in ["xxs", "xxxs"]:
            for g in [4, 6, 8]:
                assert f"spec_iter_{algo}_{drafter}_g{g}" in m["programs"]


def test_prompt_files(artifacts_dir):
    m = json.load(open(os.path.join(artifacts_dir, "manifest.json")))
    for ds, info in m["datasets"].items():
        prompts = json.load(open(os.path.join(artifacts_dir, info["file"])))
        assert len(prompts) == info["count"]
        for p in prompts[:16]:
            assert p[0] == m["bos_id"]
            assert p[1] == info["marker"]
            assert all(0 <= t < m["vocab_size"] for t in p)


def test_train_log_shows_learning(artifacts_dir):
    log = json.load(open(os.path.join(artifacts_dir, "train_log.json")))
    tgt = log["target"]
    assert tgt[-1] < tgt[0] * 0.7, "target training did not reduce loss"
    for d in ["xxs", "xxxs"]:
        kl = log[d]
        assert kl[-1] < kl[0], f"{d} distillation did not reduce KL"
