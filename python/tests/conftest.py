"""Shared fixtures for the build-path test suite."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


def random_probs(rng, *shape, conc=0.7):
    """Random rows of categorical distributions (float32)."""
    x = rng.gamma(conc, size=shape).astype(np.float32) + 1e-7
    return x / x.sum(-1, keepdims=True)


@pytest.fixture(scope="session")
def artifacts_dir():
    """The built artifact bundle, if present (integration tests)."""
    cand = os.environ.get(
        "SPECD_ARTIFACTS",
        os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
    )
    if not os.path.exists(os.path.join(cand, "manifest.json")):
        pytest.skip("artifacts not built (run `make artifacts`)")
    return cand
