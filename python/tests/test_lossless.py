"""Theorem 1 (losslessness) at the oracle level: the distribution of
SpecDec output prefixes equals ancestral sampling from M_b, for all three
verification algorithms, on small context-independent and Markov model
pairs where the exact joint is enumerable.
"""

import numpy as np
import pytest

from compile.kernels import ref


class IIDPair:
    """Context-independent (M_b, M_s) pair — the paper's §2 setting."""

    def __init__(self, pb, qb):
        self.pb = np.asarray(pb, np.float64)
        self.qb = np.asarray(qb, np.float64)
        self.vocab = len(pb)

    def target(self, _ctx):
        return self.pb

    def draft(self, _ctx):
        return self.qb


def spec_decode_prefix(pair, gamma, algo, n_tokens, rng):
    """Decode >= n_tokens via SpecDec with the given oracle verifier."""
    out = []
    layers = None
    while len(out) < n_tokens:
        ctx = out
        qs, ps, drafts = [], [], []
        c = list(ctx)
        for _ in range(gamma):
            q = pair.draft(c)
            x = int(rng.choice(pair.vocab, p=q))
            qs.append(q)
            ps.append(pair.target(c))
            drafts.append(x)
            c = c + [x]
        ps.append(pair.target(c))
        etas = rng.random(gamma)
        u = float(rng.random())
        if algo == "token":
            tau, emitted = ref.token_verify(np.array(ps), np.array(qs), drafts, etas, u)
        elif algo == "block":
            tau, emitted = ref.block_verify(np.array(ps), np.array(qs), drafts, etas, u)
        else:
            tau, emitted, layers = ref.greedy_verify(
                np.array(ps), np.array(qs), drafts, etas, u, layers
            )
        out.extend(emitted)
    return out[:n_tokens]


def exact_prefix_dist(pair, h):
    """Exact M_b^h distribution over length-h prefixes (iid pair)."""
    dist = {(): 1.0}
    for _ in range(h):
        new = {}
        for seq, p in dist.items():
            pb = pair.target(list(seq))
            for x in range(pair.vocab):
                new[seq + (x,)] = p * pb[x]
        dist = new
    return dist


@pytest.mark.parametrize("algo", ["token", "block", "greedy"])
def test_lossless_bernoulli(algo):
    """§2 example: output prefix distribution must equal M_b^h."""
    pair = IIDPair([1 / 3, 2 / 3], [2 / 3, 1 / 3])
    rng = np.random.default_rng(0)
    h, n_samples = 3, 12_000
    counts = {}
    for _ in range(n_samples):
        seq = tuple(spec_decode_prefix(pair, 2, algo, h, rng))
        counts[seq] = counts.get(seq, 0) + 1
    exact = exact_prefix_dist(pair, h)
    tv = 0.5 * sum(
        abs(counts.get(k, 0) / n_samples - v) for k, v in exact.items()
    )
    # 3 std of the multinomial TV estimator at this sample size is ~0.02
    assert tv < 0.035, f"{algo}: TV {tv}"


@pytest.mark.parametrize("algo", ["token", "block"])
def test_lossless_peaky_pair(algo):
    """Peaked target vs flat drafter (high-mismatch regime)."""
    pair = IIDPair([0.85, 0.1, 0.05], [1 / 3, 1 / 3, 1 / 3])
    rng = np.random.default_rng(1)
    h, n_samples = 2, 12_000
    counts = {}
    for _ in range(n_samples):
        seq = tuple(spec_decode_prefix(pair, 3, algo, h, rng))
        counts[seq] = counts.get(seq, 0) + 1
    exact = exact_prefix_dist(pair, h)
    tv = 0.5 * sum(abs(counts.get(k, 0) / n_samples - v) for k, v in exact.items())
    assert tv < 0.035, f"{algo}: TV {tv}"


def test_block_beats_token_on_bernoulli():
    """The §2 numbers: E[tau] = 10/9 (token) vs 11/9 (block) at gamma=2."""
    pair = IIDPair([1 / 3, 2 / 3], [2 / 3, 1 / 3])
    rng = np.random.default_rng(2)
    n = 30_000
    acc = {"token": 0, "block": 0}
    for algo in acc:
        r = np.random.default_rng(2)
        total = 0
        for _ in range(n):
            qs, ps, drafts = [], [], []
            for _ in range(2):
                q = pair.draft([])
                x = int(r.choice(2, p=q))
                qs.append(q)
                ps.append(pair.target([]))
                drafts.append(x)
            ps.append(pair.target([]))
            etas = r.random(2)
            u = float(r.random())
            fn = ref.token_verify if algo == "token" else ref.block_verify
            tau, _ = fn(np.array(ps), np.array(qs), drafts, etas, u)
            total += tau
        acc[algo] = total / n
    assert abs(acc["token"] - 10 / 9) < 0.02, acc
    assert abs(acc["block"] - 11 / 9) < 0.02, acc
