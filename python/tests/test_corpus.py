"""Corpus/workload substrate: profiles, determinism, vocabulary hygiene."""

import numpy as np

from compile import common, corpus


def test_eight_profiles_with_unique_markers():
    assert len(corpus.PROFILES) == common.NUM_DATASETS
    markers = [p.marker for p in corpus.PROFILES]
    assert len(set(markers)) == len(markers)
    for m in markers:
        assert common.MARKER_BASE <= m < common.MARKER_BASE + common.NUM_DATASETS


def test_sequences_are_well_formed():
    g = corpus.Grammar()
    rng = np.random.default_rng(0)
    for prof in corpus.PROFILES:
        for _ in range(10):
            seq = g.sample_sequence(prof, rng, max_len=64)
            assert seq[0] == common.BOS_ID
            assert seq[1] == prof.marker
            assert seq[-1] == common.EOS_ID
            assert len(seq) <= 64
            for t in seq[2:-1]:
                assert t >= common.CONTENT_BASE
                assert t < common.VOCAB_SIZE


def test_prompts_have_no_eos_and_respect_length():
    g = corpus.Grammar()
    rng = np.random.default_rng(1)
    for prof in corpus.PROFILES:
        for _ in range(10):
            p = g.sample_prompt(prof, rng)
            assert common.EOS_ID not in p
            assert len(p) <= prof.prompt_len[1] + 2
            assert len(p) >= 3


def test_grammar_deterministic_given_seed():
    a = corpus.Grammar(seed=7)
    b = corpus.Grammar(seed=7)
    np.testing.assert_array_equal(a.state_tokens, b.state_tokens)
    np.testing.assert_allclose(a.trans_scores, b.trans_scores)
    r1 = np.random.default_rng(3)
    r2 = np.random.default_rng(3)
    s1 = a.sample_sequence(corpus.PROFILES[0], r1, 48)
    s2 = b.sample_sequence(corpus.PROFILES[0], r2, 48)
    assert s1 == s2


def test_training_batch_shape_and_packing():
    g = corpus.Grammar()
    rng = np.random.default_rng(2)
    batch = corpus.training_batch(g, rng, batch=4, seq_len=96)
    assert batch.shape == (4, 96)
    assert batch.dtype == np.int32
    # packed rows: no PAD (documents are concatenated until full)
    assert (batch == common.PAD_ID).sum() == 0


def test_dataset_entropy_ordering():
    """gsm8k (temp 0.55) must be more predictable than wmt (temp 1.05):
    check the empirical unigram entropy of emissions."""
    g = corpus.Grammar()

    def entropy(prof):
        rng = np.random.default_rng(9)
        toks = []
        for _ in range(200):
            toks.extend(g.sample_sequence(prof, rng, 64)[2:-1])
        _, counts = np.unique(toks, return_counts=True)
        p = counts / counts.sum()
        return -(p * np.log(p)).sum()

    assert entropy(corpus.PROFILE_BY_NAME["gsm8k"]) < entropy(
        corpus.PROFILE_BY_NAME["wmt"]
    )
