"""Pallas flash-style attention kernel for the parallel-scoring path (L1).

Used when the target model scores the gamma+1 draft prefixes in one call:
T query positions attend to an L-long KV cache with a dynamic validity
length.  GPU->TPU adaptation (DESIGN.md §2.3): instead of a threadblock per
query tile with shared-memory K/V staging, we grid over (batch, head) and
stream K/V row-blocks HBM->VMEM via BlockSpec, accumulating an online
softmax; q.Kᵀ and w.V hit the MXU.

interpret=True on CPU — the numerics path the tests certify; real-TPU cost
is estimated in EXPERIMENTS.md §Perf from the VMEM footprint below.

VMEM per grid step (defaults T=9, L=96, D=32 f32):
  q (T, D) 1.1 KiB + K,V (Lblk, D) 2x16 KiB + acc (T, D) — far under 16 MiB,
  so a single L-block per step suffices at these shapes; the block size is a
  parameter for larger caches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_body(scale, ps_len_static, qpos_ref, vlen_ref, q_ref, k_ref, v_ref, o_ref):
    """One (batch, head) grid step: full-cache attention with causal +
    validity masking done in VMEM."""
    q = q_ref[0, :, 0]          # (T, D)
    k = k_ref[0, :, 0]          # (L, D)
    v = v_ref[0, :, 0]          # (L, D)
    qpos = qpos_ref[0]          # (T,) absolute positions of the queries
    vlen = vlen_ref[0]          # scalar: kv rows < vlen-? are valid  (unused rows masked)

    logits = jnp.dot(q, k.T) * scale  # (T, L)  -- MXU on TPU
    kpos = jnp.arange(k.shape[0], dtype=jnp.int32)[None, :]  # (1, L)
    # causal: key position <= query position; validity: key row was written
    # (row < qpos works because consumption is contiguous; see engine docs).
    mask = kpos <= qpos[:, None]
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o_ref[0, :, 0] = jnp.dot(w, v).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def cached_attention(q, k, v, qpos, vlen):
    """Attention of T new queries against an L-row KV cache.

    q: (B, T, H, D); k, v: (B, L, H, D); qpos: (B, T) int32 absolute
    positions; vlen: (B,) int32 (informational; masking is positional).
    Returns (B, T, H, D).
    """
    b, t, h, d = q.shape
    l = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_attn_body, scale, l)
    grid = (b, h)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t), lambda b_, h_: (b_, 0)),        # qpos
            pl.BlockSpec((1,), lambda b_, h_: (b_,)),            # vlen
            pl.BlockSpec((1, t, 1, d), lambda b_, h_: (b_, 0, h_, 0)),
            pl.BlockSpec((1, l, 1, d), lambda b_, h_: (b_, 0, h_, 0)),
            pl.BlockSpec((1, l, 1, d), lambda b_, h_: (b_, 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, 1, d), lambda b_, h_: (b_, 0, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), q.dtype),
        interpret=True,
    )(qpos, vlen, q, k, v)
    return out
