"""Pallas draft-verification kernels (L1) — the paper's hot-spot.

Implements Algorithm 1 (token verification) and Algorithm 2 (block
verification, Eqs. 3/4) as Pallas kernels, gridded over the batch dimension.
The greedy Appendix-C variant intentionally lives on the host-verify path
(rust `verify::greedy`) because Algorithm 6 threads state across iterations.

TPU mapping (see DESIGN.md §2.3): per grid step one batch row's
(gamma+1, V) probability block lives in VMEM (gamma=8, V=256 f32 = 9 KiB);
every reduction (Eq. 3/4 sums, inverse-CDF cumsum) is a lane-dimension
reduction over V on the VPU.  gamma is static, so the acceptance chain is a
fully unrolled dependency chain of scalar ops.  `interpret=True` everywhere:
the CPU PJRT plugin cannot execute Mosaic custom-calls, and correctness is
what the CPU path certifies.

Randomness is explicit: callers pass uniforms (etas, u_final), making the
kernels deterministic functions that can be checked against
:mod:`python.compile.kernels.ref` draw-for-draw.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-30


def _inv_cdf_idx(weights, u):
    """Inverse-CDF index over the lane dimension V.

    weights: (V,) unnormalised, non-negative. u in [0,1).
    Matches ref._inv_cdf: searchsorted(cumsum/total, u*(1-1e-7), 'right').
    """
    total = jnp.sum(weights)
    cdf = jnp.cumsum(weights) / jnp.maximum(total, EPS)
    return jnp.sum((cdf <= u * (1.0 - 1e-7)).astype(jnp.int32))


def _residual_pick(weights, fallback, u):
    """Sample from `weights`, falling back to `fallback` when degenerate."""
    use_fb = jnp.sum(weights) <= 0.0
    w = jnp.where(use_fb, fallback, weights)
    return _inv_cdf_idx(w, u)


def _emit(drafts, tau, y, gamma, pad_id):
    """emitted[j] = drafts[j] for j < tau; y at j == tau; pad after."""
    idx = jnp.arange(gamma + 1, dtype=jnp.int32)
    drafts_ext = jnp.concatenate([drafts, jnp.zeros((1,), drafts.dtype)])
    out = jnp.where(idx < tau, drafts_ext, pad_id)
    return jnp.where(idx == tau, y, out)


def _token_body(gamma, pad_id, ps_ref, qs_ref, d_ref, eta_ref, u_ref,
                emit_ref, tau_ref):
    ps = ps_ref[0]          # (gamma+1, V)
    qs = qs_ref[0]          # (gamma, V)
    drafts = d_ref[0]       # (gamma,)
    etas = eta_ref[0]       # (gamma,)
    u = u_ref[0]

    # Algorithm 1: accept while eta_i <= min(1, p/q); stop at first reject.
    # Data-independent form: tau = count of prefix-all-accepted positions.
    ratios = jnp.stack(
        [ps[i, drafts[i]] / jnp.maximum(qs[i, drafts[i]], EPS) for i in range(gamma)]
    )
    accept = etas <= jnp.minimum(ratios, 1.0)
    # prefix products: accepted up to first failure
    pref = jnp.cumprod(accept.astype(jnp.int32))
    tau = jnp.sum(pref).astype(jnp.int32)

    res_rows = jnp.stack(
        [jnp.maximum(ps[i] - qs[i], 0.0) for i in range(gamma)]
        + [ps[gamma]]  # tau == gamma: bonus token straight from M_b
    )
    res = res_rows[tau]
    y = _residual_pick(res, ps[tau], u)
    tau_ref[0] = tau
    emit_ref[0] = _emit(drafts, tau, y, gamma, pad_id)


def _block_body(gamma, pad_id, ps_ref, qs_ref, d_ref, eta_ref, u_ref,
                emit_ref, tau_ref, p_ref, h_ref):
    ps = ps_ref[0]
    qs = qs_ref[0]
    drafts = d_ref[0]
    etas = eta_ref[0]
    u = u_ref[0]

    # Algorithm 2: coupled chain p_i = min(1, p_{i-1} * Mb/Ms), Eq. (4) h_i.
    p_list = [jnp.float32(1.0)]
    h_list = [jnp.float32(1.0)]  # h_0 unused
    for i in range(1, gamma + 1):
        x = drafts[i - 1]
        ratio = ps[i - 1, x] / jnp.maximum(qs[i - 1, x], EPS)
        p_i = jnp.minimum(p_list[i - 1] * ratio, 1.0)
        p_list.append(p_i)
        if i == gamma:
            h_list.append(p_i)
        else:
            s_i = jnp.sum(jnp.maximum(p_i * ps[i] - qs[i], 0.0))
            denom = s_i + 1.0 - p_i
            h_list.append(jnp.where(denom <= EPS, 1.0, s_i / denom))
    p = jnp.stack(p_list)   # (gamma+1,)
    h = jnp.stack(h_list)   # (gamma+1,)

    # No break: tau = longest accepted sub-block = max accepted index.
    idx = jnp.arange(1, gamma + 1, dtype=jnp.int32)
    accepted = etas <= h[1:]
    tau = jnp.max(jnp.where(accepted, idx, 0)).astype(jnp.int32)

    # Residual (Eq. 3) with p_tau coupling; bonus from M_b when tau == gamma.
    res_rows = jnp.stack(
        [jnp.maximum(p[i] * ps[i] - qs[i], 0.0) for i in range(gamma)]
        + [ps[gamma]]
    )
    res = res_rows[tau]
    y = _residual_pick(res, ps[tau], u)
    tau_ref[0] = tau
    emit_ref[0] = _emit(drafts, tau, y, gamma, pad_id)
    p_ref[0] = p
    h_ref[0] = h


def _specs(batch, gamma, vocab):
    row = lambda *dims: pl.BlockSpec((1,) + dims, lambda b: (b,) + (0,) * len(dims))
    in_specs = [
        row(gamma + 1, vocab),  # ps
        row(gamma, vocab),      # qs
        row(gamma),             # drafts
        row(gamma),             # etas
        pl.BlockSpec((1,), lambda b: (b,)),  # u
    ]
    return in_specs


@functools.partial(jax.jit, static_argnames=("pad_id",))
def token_verify(ps, qs, drafts, etas, us, *, pad_id: int = 0):
    """Batched Algorithm 1. Shapes: ps (B, g+1, V), qs (B, g, V),
    drafts/etas (B, g), us (B,). Returns (emitted (B, g+1) i32, tau (B,) i32).
    """
    batch, g1, vocab = ps.shape
    gamma = g1 - 1
    kernel = functools.partial(_token_body, gamma, pad_id)
    out = pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=_specs(batch, gamma, vocab),
        out_specs=[
            pl.BlockSpec((1, gamma + 1), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, gamma + 1), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        ],
        interpret=True,
    )(ps, qs, drafts.astype(jnp.int32), etas, us)
    return out[0], out[1]


@functools.partial(jax.jit, static_argnames=("pad_id", "debug"))
def block_verify(ps, qs, drafts, etas, us, *, pad_id: int = 0, debug: bool = False):
    """Batched Algorithm 2.  With ``debug=True`` additionally returns the
    acceptance chain (p, h) for property tests against the oracle."""
    batch, g1, vocab = ps.shape
    gamma = g1 - 1
    kernel = functools.partial(_block_body, gamma, pad_id)
    out = pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=_specs(batch, gamma, vocab),
        out_specs=[
            pl.BlockSpec((1, gamma + 1), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1, gamma + 1), lambda b: (b, 0)),
            pl.BlockSpec((1, gamma + 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, gamma + 1), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((batch, gamma + 1), jnp.float32),
            jax.ShapeDtypeStruct((batch, gamma + 1), jnp.float32),
        ],
        interpret=True,
    )(ps, qs, drafts.astype(jnp.int32), etas, us)
    if debug:
        return out[0], out[1], out[2], out[3]
    return out[0], out[1]


VERIFIERS = {"token": token_verify, "block": block_verify}
