"""Pure-numpy verification oracles — the correctness reference for L1.

Direct, readable ports of the paper's Algorithms 1 (token verification),
2 (block verification, Eqs. 3/4) and 4 (greedy block verification,
Appendix C), matching the Appendix A sketches but with *explicit* randomness:
every function takes the uniform variates as arguments so the Pallas kernels
(and the rust implementations, via golden vectors) can be checked
bit-for-bit against the same draws.

Conventions (one batch row):
  ps     : (gamma+1, V) — ps[i] = M_b(. | c, X^i), ps[0] = M_b(. | c)
  qs     : (gamma,   V) — qs[i] = M_s(. | c, X^i)
  drafts : (gamma,) int — X_1..X_gamma
  etas   : (gamma,) f32 — per-position accept/reject uniforms
  u_final: f32          — inverse-CDF uniform for the bonus/residual token
Returns (tau, emitted) where emitted = [X_1..X_tau, Y] (length tau+1).
"""

from __future__ import annotations

import numpy as np

EPS = 1e-30


def _inv_cdf(weights: np.ndarray, u: float) -> int:
    """Sample index via inverse CDF on (possibly unnormalised) weights."""
    total = float(weights.sum())
    if total <= 0.0:
        # Degenerate residual (ps == qs exactly): callers fall back to ps.
        return 0
    cdf = np.cumsum(weights) / total
    return int(np.searchsorted(cdf, u * (1.0 - 1e-7), side="right"))


def token_verify(ps, qs, drafts, etas, u_final):
    """Paper Algorithm 1 (standard speculative-decoding verification)."""
    ps, qs = np.asarray(ps, np.float64), np.asarray(qs, np.float64)
    gamma = len(drafts)
    tau = 0
    for i in range(gamma):
        x = int(drafts[i])
        ratio = ps[i, x] / max(qs[i, x], EPS)
        if etas[i] <= min(ratio, 1.0):
            tau = i + 1
        else:
            break
    if tau == gamma:
        y = _inv_cdf(ps[gamma], u_final)
    else:
        res = np.maximum(ps[tau] - qs[tau], 0.0)
        if res.sum() <= 0.0:
            res = ps[tau]
        y = _inv_cdf(res, u_final)
    return tau, list(map(int, drafts[:tau])) + [y]


def block_chain(ps, qs, drafts):
    """The coupled acceptance chain of Algorithm 2.

    Returns (p, h) with p[i] = p_i (Eq. 8), i in 0..gamma, and
    h[i] = h_i (Eq. 4) for i in 1..gamma (index 0 unused).
    """
    ps, qs = np.asarray(ps, np.float64), np.asarray(qs, np.float64)
    gamma = len(drafts)
    p = np.zeros(gamma + 1)
    h = np.zeros(gamma + 1)
    p[0] = 1.0
    h[0] = 1.0  # unused sentinel, kept for parity with the kernel
    for i in range(1, gamma + 1):
        x = int(drafts[i - 1])
        ratio = ps[i - 1, x] / max(qs[i - 1, x], EPS)
        p[i] = min(p[i - 1] * ratio, 1.0)
        if i == gamma:
            h[i] = p[i]
        else:
            s_i = np.maximum(p[i] * ps[i] - qs[i], 0.0).sum()
            denom = s_i + 1.0 - p[i]
            h[i] = 1.0 if denom <= EPS else s_i / denom
    return p, h


def block_verify(ps, qs, drafts, etas, u_final):
    """Paper Algorithm 2 (block verification). NEVER breaks early: scans the
    whole block and keeps the longest accepted sub-block."""
    ps, qs = np.asarray(ps, np.float64), np.asarray(qs, np.float64)
    gamma = len(drafts)
    p, h = block_chain(ps, qs, drafts)
    tau = 0
    for i in range(1, gamma + 1):
        if etas[i - 1] <= h[i]:
            tau = i
    if tau == gamma:
        y = _inv_cdf(ps[gamma], u_final)
    else:
        res = np.maximum(p[tau] * ps[tau] - qs[tau], 0.0)
        if res.sum() <= 0.0:
            res = ps[tau]
        y = _inv_cdf(res, u_final)
    return tau, list(map(int, drafts[:tau])) + [y]


def greedy_verify(ps, qs, drafts, etas, u_final, layers=None):
    """Paper Algorithm 4 (greedy block verification, Appendix C) with the
    Algorithm 5/6 distribution modification.

    Algorithm 5 (Eq. 23) defines the modified target via *joint* sequence
    probabilities: ``M_new(x_i | .) ∝ max(M_b(c, X^tau, Y, x^i) -
    M_s(c, X^tau, Y, x^i), 0)``.  Factoring the joints, the modified row at
    a window position is ``norm(max(M_row - R * Ms_row, 0))`` where ``R`` is
    the running ratio Ms_joint / M_joint accumulated along every token
    emitted since the window opened (M = the composite target the window was
    created against).  Because Algorithm 6 re-modifies the current (already
    composite) target on each rejection, windows nest: state is a list of
    *layers*, oldest first, each ``(remaining_positions, ratio)``.

    Returns (tau, emitted, new_layers).
    """
    ps = np.asarray(ps, np.float64)
    qs = np.asarray(qs, np.float64)
    gamma = len(drafts)
    layers = list(layers) if layers else []
    n_layers = len(layers)

    def norm_or(row, fallback):
        tot = row.sum()
        return row / tot if tot > 0 else fallback.copy()

    # Walk positions 0..gamma building composite rows and layer-ratio
    # snapshots along the draft path.
    comp = []            # composite target row per position
    below = []           # below[i][l] = composite with layers < l applied
    ratio_snap = []      # ratio_snap[i][l] = layer ratio BEFORE consuming pos i
    cur_r = [r for (_rem, r) in layers]
    for i in range(gamma + 1):
        row = ps[i].copy()
        below_i = []
        for l, (rem, _r0) in enumerate(layers):
            below_i.append(row.copy())
            if rem > i and i < gamma:
                row = norm_or(np.maximum(row - cur_r[l] * qs[i], 0.0), qs[i])
        comp.append(row)
        below.append(below_i)
        ratio_snap.append(list(cur_r))
        if i < gamma:
            x = int(drafts[i])
            for l, (rem, _r0) in enumerate(layers):
                if rem > i:
                    cur_r[l] *= qs[i, x] / max(below_i[l][x], EPS)

    # Algorithm 4 proper, against the composite rows.
    ptilde = np.zeros(gamma + 1)
    ptilde[0] = 1.0
    tau = 0
    for i in range(1, gamma):
        x = int(drafts[i - 1])
        ptilde[i] = ptilde[i - 1] * comp[i - 1][x] / max(qs[i - 1, x], EPS)
        p_remain = np.maximum(ptilde[i] * comp[i] - qs[i], 0.0).sum()
        p_rej = np.maximum(qs[i] - ptilde[i] * comp[i], 0.0).sum()
        h_i = 1.0 if p_rej <= EPS else min(1.0, p_remain / p_rej)
        if etas[i - 1] <= h_i:
            tau = i
    x = int(drafts[gamma - 1])
    ptilde[gamma] = ptilde[gamma - 1] * comp[gamma - 1][x] / max(qs[gamma - 1, x], EPS)
    if etas[gamma - 1] <= ptilde[gamma]:
        tau = gamma
        y = _inv_cdf(comp[gamma], u_final)
    else:
        res = np.maximum(ptilde[tau] * comp[tau] - qs[tau], 0.0)
        if res.sum() <= 0.0:
            res = comp[tau]
        y = _inv_cdf(res, u_final)

    # Build the next-iteration layer state: surviving old layers (ratios
    # advanced through the emitted tokens X^tau and Y), plus the new window.
    new_layers = []
    for l, (rem, _r0) in enumerate(layers):
        rem2 = rem - (tau + 1)
        if rem2 <= 0:
            continue
        r = ratio_snap[tau][l]  # advanced through X^tau during the walk
        # advance through Y at position tau (layer is active there: rem > tau)
        if tau < gamma:
            r *= qs[tau, y] / max(below[tau][l][y], EPS)
        new_layers.append((rem2, r))
    if tau < gamma and gamma - tau - 1 > 0:
        r_new = 1.0
        for i in range(tau):
            xi = int(drafts[i])
            r_new *= qs[i, xi] / max(comp[i][xi], EPS)
        r_new *= qs[tau, y] / max(comp[tau][y], EPS)
        new_layers.append((gamma - tau - 1, r_new))
    _ = n_layers
    return tau, list(map(int, drafts[:tau])) + [y], new_layers


def sample_categorical(probs: np.ndarray, u: float) -> int:
    """Inverse-CDF categorical draw (shared with baseline decoding)."""
    return _inv_cdf(np.asarray(probs, np.float64), u)


def reference_attention(q, k, v, mask):
    """Attention oracle for the Pallas attention kernel.

    q: (T, H, D), k/v: (S, H, D), mask: (T, S) bool (True = attend).
    """
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    scale = 1.0 / np.sqrt(q.shape[-1])
    out = np.zeros_like(q)
    for h in range(q.shape[1]):
        logits = (q[:, h] @ k[:, h].T) * scale  # (T, S)
        logits = np.where(mask, logits, -1e30)
        logits = logits - logits.max(axis=-1, keepdims=True)
        w = np.exp(logits)
        w = w / w.sum(axis=-1, keepdims=True)
        out[:, h] = w @ v[:, h]
    return out
