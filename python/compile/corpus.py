"""Synthetic multi-domain corpus (the dataset substitution, DESIGN.md §2.2).

The paper evaluates on eight public/proprietary prompt datasets with PALM-2
models.  We replace them with a *learnable* synthetic language: a hidden-state
Markov emitter ("grammar") whose per-domain statistics (transition
peakedness, emission entropy, prompt length) differ, mirroring how GSM8K is
more predictable than WMT for a fixed drafter.  One LM family is trained on
the mixture; the domain marker token lets it condition per-dataset, so the
per-dataset spread in acceptance rates emerges exactly as in the paper.

Deterministic given (dataset, seed): the prompt sets exported to
``artifacts/prompts_<ds>.json`` are the canonical eval workload shared by the
rust benches.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import common


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    """Statistics of one synthetic "dataset" (paper Table 1 rows)."""

    name: str
    marker: int  # domain marker token id
    trans_temp: float  # hidden-state transition temperature (lower = more predictable)
    emit_temp: float  # emission temperature (lower = peakier next-token dist)
    prompt_len: tuple[int, int]  # (min, max) prompt content tokens
    eos_rate: float  # per-sentence-boundary probability of ending generation


# Ordered so that the expected block-efficiency ordering resembles Table 1:
# gsm8k most predictable (paper BE 3.81), wmt/lm1b least (3.19/3.21).
PROFILES = [
    DatasetProfile("lm1b", common.MARKER_BASE + 0, 1.00, 1.00, (8, 28), 0.06),
    DatasetProfile("gptprompt", common.MARKER_BASE + 1, 0.75, 0.80, (10, 30), 0.05),
    DatasetProfile("webqa", common.MARKER_BASE + 2, 0.80, 0.78, (6, 20), 0.07),
    DatasetProfile("piqa", common.MARKER_BASE + 3, 0.82, 0.82, (8, 24), 0.06),
    DatasetProfile("sharegpt", common.MARKER_BASE + 4, 0.88, 0.88, (12, 32), 0.05),
    DatasetProfile("xsum", common.MARKER_BASE + 5, 0.78, 0.76, (14, 32), 0.06),
    DatasetProfile("gsm8k", common.MARKER_BASE + 6, 0.55, 0.55, (10, 26), 0.04),
    DatasetProfile("wmt", common.MARKER_BASE + 7, 1.05, 1.05, (10, 28), 0.06),
]
PROFILE_BY_NAME = {p.name: p for p in PROFILES}
assert len(PROFILES) == common.NUM_DATASETS


class Grammar:
    """Hidden-state Markov emitter shared across domains.

    ``n_states`` hidden states; each state owns a bank of content tokens with
    a peaked score vector.  Domains re-temper the *same* underlying tables so
    the LM can share structure across domains (as a real multi-task LM does).
    """

    N_STATES = 12
    TOKENS_PER_STATE = 14

    def __init__(self, seed: int = 1234):
        rng = np.random.default_rng(seed)
        n_content = common.VOCAB_SIZE - common.CONTENT_BASE
        # Each state's token bank: a window of content tokens (overlapping).
        self.state_tokens = np.stack(
            [
                common.CONTENT_BASE
                + (rng.permutation(n_content)[: self.TOKENS_PER_STATE])
                for _ in range(self.N_STATES)
            ]
        )  # (S, T)
        # Raw emission scores: one clear favourite + decaying tail.
        self.emit_scores = np.sort(rng.gumbel(size=(self.N_STATES, self.TOKENS_PER_STATE)))[
            :, ::-1
        ] * 1.6
        # Raw transition scores: SECOND-ORDER (depend on the previous two
        # hidden states).  This is the capacity knife between the model
        # sizes: the 3-layer target tracks two states of history, the tiny
        # drafters approximate an order-1 chain, giving the moderate
        # drafter-acceptance regime of the paper (PALM-2-XXS vs -S).
        self.trans_scores = rng.gumbel(size=(self.N_STATES, self.N_STATES, self.N_STATES)) * 1.4
        # "Sentence boundary" states: reaching them may emit EOS.
        self.boundary_states = np.array([0, 5, 9])

    @staticmethod
    def _softmax(scores: np.ndarray, temp: float) -> np.ndarray:
        z = scores / max(temp, 1e-3)
        z = z - z.max(axis=-1, keepdims=True)
        e = np.exp(z)
        return e / e.sum(axis=-1, keepdims=True)

    def sample_sequence(
        self,
        profile: DatasetProfile,
        rng: np.random.Generator,
        max_len: int,
    ) -> list[int]:
        """One full document: [BOS, marker, content..., EOS]."""
        trans = self._softmax(self.trans_scores, profile.trans_temp)
        emit = self._softmax(self.emit_scores, profile.emit_temp)
        toks = [common.BOS_ID, profile.marker]
        prev = int(rng.integers(self.N_STATES))
        state = int(rng.integers(self.N_STATES))
        while len(toks) < max_len - 1:
            bank = self.state_tokens[state]
            tok = int(rng.choice(bank, p=emit[state]))
            toks.append(tok)
            prev, state = state, int(rng.choice(self.N_STATES, p=trans[prev, state]))
            if state in self.boundary_states and rng.random() < profile.eos_rate:
                break
        toks.append(common.EOS_ID)
        return toks

    def sample_prompt(
        self, profile: DatasetProfile, rng: np.random.Generator
    ) -> list[int]:
        """Prompt prefix only (no EOS): what the serving workload submits."""
        lo, hi = profile.prompt_len
        want = int(rng.integers(lo, hi + 1))
        seq = self.sample_sequence(profile, rng, max_len=want + 8)
        seq = [t for t in seq if t != common.EOS_ID]
        return seq[: max(want, 3)]


def training_batch(
    grammar: Grammar, rng: np.random.Generator, batch: int, seq_len: int
) -> np.ndarray:
    """Mixture-of-domains LM training batch, PAD-padded to ``seq_len``."""
    out = np.full((batch, seq_len), common.PAD_ID, dtype=np.int32)
    for b in range(batch):
        profile = PROFILES[int(rng.integers(len(PROFILES)))]
        # Pack documents until the row is full to avoid wasting positions.
        row: list[int] = []
        while len(row) < seq_len:
            row.extend(grammar.sample_sequence(profile, rng, max_len=seq_len))
        out[b] = np.asarray(row[:seq_len], dtype=np.int32)
    return out
