"""Training + distillation pipeline (build-time only).

Produces the PALM-2 substitution (DESIGN.md §2.2):
  * `target` — trained on the synthetic multi-domain corpus with the standard
    next-token NLL loss.
  * `xxs`, `xxxs` — drafters distilled from the target (forward-KL on the
    target's full next-token distribution), with `xxs` given a bigger model
    and more steps so the paper's drafter-quality ordering holds.

Optimiser is a hand-rolled Adam (no optax in the image).  Everything is
deterministic given the seeds.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common, corpus, model


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.int32(0)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}


def nll_loss(cfg, params, tokens):
    """Next-token NLL, ignoring positions whose *target* is PAD."""
    logp = model.forward_train(cfg, params, tokens)  # (B, T, V)
    tgt = tokens[:, 1:]
    lp = jnp.take_along_axis(logp[:, :-1], tgt[..., None], axis=-1)[..., 0]
    mask = (tgt != common.PAD_ID).astype(jnp.float32)
    return -(lp * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def distill_loss(cfg_s, params_s, teacher_logp, tokens):
    """Forward KL(teacher || student) on every position."""
    logp_s = model.forward_train(cfg_s, params_s, tokens)
    p_t = jnp.exp(teacher_logp)
    mask = (tokens[:, 1:] != common.PAD_ID).astype(jnp.float32)
    kl = (p_t * (teacher_logp - logp_s)).sum(-1)[:, :-1]
    return (kl * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def train_target(cfg, grammar, *, steps, batch, seq_len, lr, seed=0, log_every=50):
    rng = np.random.default_rng(seed)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    state = adam_init(params)

    @jax.jit
    def step_fn(params, state, tokens):
        loss, grads = jax.value_and_grad(lambda p: nll_loss(cfg, p, tokens))(params)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss

    t0 = time.time()
    losses = []
    for s in range(steps):
        tokens = jnp.asarray(corpus.training_batch(grammar, rng, batch, seq_len))
        params, state, loss = step_fn(params, state, tokens)
        losses.append(float(loss))
        if log_every and (s % log_every == 0 or s == steps - 1):
            print(
                f"[train:{cfg.name}] step {s:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params, losses


def distill(cfg_t, params_t, cfg_s, grammar, *, steps, batch, seq_len, lr, seed=1,
            log_every=50):
    rng = np.random.default_rng(seed + 100)
    params_s = model.init_params(cfg_s, jax.random.PRNGKey(seed))
    state = adam_init(params_s)

    @jax.jit
    def step_fn(params_s, state, tokens):
        teacher_logp = jax.lax.stop_gradient(model.forward_train(cfg_t, params_t, tokens))
        loss, grads = jax.value_and_grad(
            lambda p: distill_loss(cfg_s, p, teacher_logp, tokens)
        )(params_s)
        params_s, state = adam_update(params_s, grads, state, lr)
        return params_s, state, loss

    t0 = time.time()
    losses = []
    for s in range(steps):
        tokens = jnp.asarray(corpus.training_batch(grammar, rng, batch, seq_len))
        params_s, state, loss = step_fn(params_s, state, tokens)
        losses.append(float(loss))
        if log_every and (s % log_every == 0 or s == steps - 1):
            print(
                f"[distill:{cfg_s.name}] step {s:4d} KL {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params_s, losses


def train_all(fast: bool = False):
    """Train the whole family. ``fast`` shrinks steps for CI smoke runs."""
    grammar = corpus.Grammar()
    scale = 0.1 if fast else 1.0
    steps_t = max(20, int(common.TRAIN_STEPS * scale))
    steps_xxs = max(15, int(common.DISTILL_STEPS_XXS * scale))
    steps_xxxs = max(10, int(common.DISTILL_STEPS_XXXS * scale))
    kw = dict(batch=common.TRAIN_BATCH, seq_len=common.TRAIN_SEQ, lr=common.LEARNING_RATE)
    params_t, loss_t = train_target(common.TARGET, grammar, steps=steps_t, **kw)
    params_xxs, loss_xxs = distill(
        common.TARGET, params_t, common.XXS, grammar, steps=steps_xxs, **kw
    )
    params_xxxs, loss_xxxs = distill(
        common.TARGET, params_t, common.XXXS, grammar, steps=steps_xxxs, **kw
    )
    return {
        "target": (params_t, loss_t),
        "xxs": (params_xxs, loss_xxs),
        "xxxs": (params_xxxs, loss_xxxs),
    }
