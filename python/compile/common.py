"""Shared configuration for the build-time (L1/L2) python stack.

Everything here is compile-path only: these configs decide the fixed shapes
baked into the AOT HLO programs.  The rust runtime reads the same values back
from ``artifacts/manifest.json`` and never imports this module.
"""

from __future__ import annotations

import dataclasses
import os

# ---------------------------------------------------------------------------
# Vocabulary layout (byte-level synthetic language).
# ---------------------------------------------------------------------------
VOCAB_SIZE = 256
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
# Dataset-domain marker tokens occupy 3..10 (8 synthetic "datasets").
MARKER_BASE = 3
NUM_DATASETS = 8
CONTENT_BASE = 16  # first ordinary content token


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A decoder-only transformer LM variant."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    vocab_size: int = VOCAB_SIZE
    max_len: int = 96  # prompt + generation + draft scratch

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def param_count(self) -> int:
        per_layer = 4 * self.d_model**2 + 2 * self.d_model * self.d_ff
        per_layer += 4 * self.d_model  # layernorm scales/biases
        return (
            self.vocab_size * self.d_model  # tied embedding / unembedding
            + self.max_len * self.d_model  # learned positions
            + self.n_layers * per_layer
            + 2 * self.d_model  # final LN
        )


# The PALM-2-{S, XXS, XXXS} substitution (see DESIGN.md §2.2): a trained
# target and two distilled drafters with a strict quality ordering.
TARGET = ModelConfig("target", n_layers=3, d_model=128, n_heads=4)
XXS = ModelConfig("xxs", n_layers=2, d_model=64, n_heads=4)
XXXS = ModelConfig("xxxs", n_layers=1, d_model=32, n_heads=2)
VARIANTS = {m.name: m for m in (TARGET, XXS, XXXS)}
DRAFTERS = ("xxs", "xxxs")

# Fixed serving shapes baked into the AOT programs.
BATCH = 4  # engine slot count per program
MAX_LEN = TARGET.max_len
GAMMAS = (4, 6, 8)
ALGOS = ("token", "block")  # fused in-HLO verification variants
# "greedy" (Appendix C) runs through the host-verify path, see engine/.

# Training schedule (overridable for CI smoke runs).
TRAIN_STEPS = int(os.environ.get("SPECD_TRAIN_STEPS", "700"))
DISTILL_STEPS_XXS = int(os.environ.get("SPECD_DISTILL_STEPS", "400"))
DISTILL_STEPS_XXXS = int(os.environ.get("SPECD_DISTILL_STEPS_XXXS", "250"))
TRAIN_BATCH = 8
TRAIN_SEQ = MAX_LEN
LEARNING_RATE = 3e-3

# Workload export: prompts per dataset written to artifacts/prompts_<ds>.json.
PROMPTS_PER_DATASET = int(os.environ.get("SPECD_PROMPTS", "256"))
