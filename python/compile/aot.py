"""AOT export: train the model family, lower every serving program to HLO
text, and write the artifact bundle consumed by the rust runtime.

Run once via ``make artifacts``.  Python never runs after this.

Bundle layout (artifacts/):
  manifest.json            — shapes, program arg/out signatures, profiles
  weights_<model>.bin      — raw little-endian f32, tree-flatten order
  <program>.hlo.txt        — HLO text (NOT serialized proto: jax>=0.5 emits
                             64-bit instruction ids that xla_extension 0.5.1
                             rejects; the text parser reassigns ids)
  prompts_<dataset>.json   — canonical eval prompts per synthetic dataset
  golden_verify.json       — draw-for-draw verification test vectors for the
                             rust `verify` module
  train_log.json           — loss curves (EXPERIMENTS.md provenance)
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import common, corpus, model, train
from .kernels import ref


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(fn, *example_args) -> tuple[str, list[dict], list[dict]]:
    """Lower ``fn`` to HLO text plus its flattened arg/out signatures.

    ``keep_unused=True`` is load-bearing: the rust runtime feeds arguments
    positionally in tree-flatten order, so jax must not prune parameters the
    program happens to ignore (e.g. prefill's ``length``).
    """
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    flat, _ = jax.tree_util.tree_flatten_with_path(example_args)
    args = [
        {
            "name": jax.tree_util.keystr(path),
            "shape": list(np.shape(leaf)),
            "dtype": str(np.asarray(leaf).dtype),
        }
        for path, leaf in flat
    ]
    out_flat, _ = jax.tree_util.tree_flatten(jax.eval_shape(fn, *example_args))
    outs = [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in out_flat]
    return comp.as_hlo_text(), args, outs


def flatten_params(params) -> tuple[list[tuple[str, np.ndarray]], int]:
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    named = [(jax.tree_util.keystr(p), np.asarray(x, np.float32)) for p, x in flat]
    total = sum(int(x.size) for _, x in named)
    return named, total


def write_weights(path: str, params) -> list[dict]:
    named, _ = flatten_params(params)
    entries, offset = [], 0
    with open(path, "wb") as f:
        for name, arr in named:
            data = np.ascontiguousarray(arr, dtype="<f4").tobytes()
            f.write(data)
            entries.append({"name": name, "shape": list(arr.shape), "offset": offset})
            offset += arr.size
    return entries


# ---------------------------------------------------------------------------
# Program definitions (the export surface; see model.py for the contract)
# ---------------------------------------------------------------------------


def build_programs(params):
    """Yield (program_name, fn, example_args, meta) for every export."""
    B, L = common.BATCH, common.MAX_LEN
    toks = jnp.zeros((B, L), jnp.int32)
    length = jnp.ones((B,), jnp.int32)
    seed = jnp.int32(0)

    cfgs = common.VARIANTS
    kv = {name: model.init_kv(cfg, B) for name, cfg in cfgs.items()}

    for name, cfg in cfgs.items():
        p = params[name]
        yield (
            f"prefill_{name}",
            functools.partial(model.prefill, cfg),
            (p, toks, length),
            {"kind": "prefill", "model": name},
        )

    tcfg, tp = cfgs["target"], params["target"]
    for drafter in common.DRAFTERS:
        dcfg, dp = cfgs[drafter], params[drafter]
        for gamma in common.GAMMAS:
            for algo in common.ALGOS:
                fn = functools.partial(
                    _spec_iter_export, tcfg, dcfg, gamma=gamma, algo=algo, max_len=L
                )
                yield (
                    f"spec_iter_{algo}_{drafter}_g{gamma}",
                    fn,
                    (tp, dp, toks, length, kv["target"], kv[drafter], seed),
                    {"kind": "spec_iter", "algo": algo, "drafter": drafter, "gamma": gamma},
                )
            # host-verify path: draft block only (greedy & debugging)
            yield (
                f"draft_block_{drafter}_g{gamma}",
                functools.partial(_draft_block_export, dcfg, gamma=gamma),
                (dp, toks, length, kv[drafter], seed),
                {"kind": "draft_block", "drafter": drafter, "gamma": gamma},
            )

    for gamma in common.GAMMAS:
        yield (
            f"target_score_g{gamma}",
            functools.partial(_target_score_export, tcfg, gamma=gamma),
            (tp, toks, length, kv["target"], jnp.zeros((B, gamma), jnp.int32)),
            {"kind": "target_score", "gamma": gamma},
        )

    yield (
        "baseline_step",
        functools.partial(_baseline_export, tcfg, max_len=L),
        (tp, toks, length, kv["target"], seed),
        {"kind": "baseline"},
    )


def _spec_iter_export(tcfg, dcfg, tp, dp, toks, length, kvt, kvd, seed, *, gamma, algo, max_len):
    return model.spec_iter(
        tcfg, dcfg, tp, dp, toks, length, kvt, kvd, seed,
        gamma=gamma, algo=algo, max_len=max_len,
    )


def _draft_block_export(dcfg, dp, toks, length, kvd, seed, *, gamma):
    key = jax.random.PRNGKey(seed)
    drafts, qs, kvd = model.draft_scan(dcfg, dp, kvd, toks, length, gamma, key)
    return drafts, qs, kvd


def _target_score_export(tcfg, tp, toks, length, kvt, drafts, *, gamma):
    ps, kvt = model.target_score(tcfg, tp, kvt, toks, length, drafts)
    return ps, kvt


def _baseline_export(tcfg, tp, toks, length, kvt, seed, *, max_len):
    return model.baseline_step(tcfg, tp, toks, length, kvt, seed, max_len=max_len)


# ---------------------------------------------------------------------------
# Eval prompt + golden vector export
# ---------------------------------------------------------------------------


def export_prompts(outdir: str, grammar: corpus.Grammar, n: int) -> dict:
    info = {}
    for prof in corpus.PROFILES:
        rng = np.random.default_rng(hash(prof.name) % 2**31)
        prompts = [grammar.sample_prompt(prof, rng) for _ in range(n)]
        path = os.path.join(outdir, f"prompts_{prof.name}.json")
        with open(path, "w") as f:
            json.dump(prompts, f)
        info[prof.name] = {
            "file": os.path.basename(path),
            "marker": prof.marker,
            "count": n,
            "mean_len": float(np.mean([len(p) for p in prompts])),
        }
    return info


def export_golden(outdir: str, n_cases: int = 64) -> None:
    """Draw-for-draw test vectors: rust `verify` must match these exactly."""
    rng = np.random.default_rng(20250710)
    cases = []
    for i in range(n_cases):
        gamma = int(rng.choice([1, 2, 4, 6, 8]))
        vocab = int(rng.choice([8, 32, 256]))
        conc = float(rng.choice([0.3, 1.0, 5.0]))
        ps = rng.gamma(conc, size=(gamma + 1, vocab))
        qs = rng.gamma(conc, size=(gamma, vocab))
        ps /= ps.sum(-1, keepdims=True)
        qs /= qs.sum(-1, keepdims=True)
        if i % 4 == 0:  # identical-model edge case
            qs = ps[:gamma].copy()
        drafts = np.array([rng.choice(vocab, p=qs[j]) for j in range(gamma)])
        etas = rng.random(gamma)
        u = float(rng.random())
        tok_tau, tok_em = ref.token_verify(ps, qs, drafts, etas, u)
        blk_tau, blk_em = ref.block_verify(ps, qs, drafts, etas, u)
        p_chain, h_chain = ref.block_chain(ps, qs, drafts)
        # random greedy modification-window state (Algorithm 5/6 layers)
        layers = []
        if gamma > 1 and rng.random() < 0.6:
            layers.append((int(rng.integers(1, gamma)), float(rng.uniform(0.2, 2.0))))
            if gamma > 2 and rng.random() < 0.3:
                layers.append((int(rng.integers(1, gamma - 1)), float(rng.uniform(0.2, 2.0))))
        g_tau, g_em, g_new = ref.greedy_verify(ps, qs, drafts, etas, u, layers)
        cases.append(
            {
                "gamma": gamma,
                "vocab": vocab,
                "ps": ps.flatten().tolist(),
                "qs": qs.flatten().tolist(),
                "drafts": drafts.tolist(),
                "etas": etas.tolist(),
                "u": u,
                "token": {"tau": tok_tau, "emitted": tok_em},
                "block": {
                    "tau": blk_tau,
                    "emitted": blk_em,
                    "p": p_chain.tolist(),
                    "h": h_chain.tolist(),
                },
                "greedy": {
                    "tau": g_tau,
                    "emitted": g_em,
                    "layers_in": [[r, v] for r, v in layers],
                    "layers_out": [[r, v] for r, v in g_new],
                },
            }
        )
    with open(os.path.join(outdir, "golden_verify.json"), "w") as f:
        json.dump(cases, f)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--fast", action="store_true", help="CI smoke build")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    t_start = time.time()

    fast = args.fast or os.environ.get("SPECD_FAST") == "1"
    print(f"[aot] training model family (fast={fast}) ...", flush=True)
    trained = train.train_all(fast=fast)
    params = {k: v[0] for k, v in trained.items()}
    with open(os.path.join(outdir, "train_log.json"), "w") as f:
        json.dump({k: v[1] for k, v in trained.items()}, f)

    models_meta = {}
    for name, cfg in common.VARIANTS.items():
        weights = write_weights(os.path.join(outdir, f"weights_{name}.bin"), params[name])
        models_meta[name] = {
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "vocab_size": cfg.vocab_size,
            "max_len": cfg.max_len,
            "param_count": cfg.param_count(),
            "weights_file": f"weights_{name}.bin",
            "weights": weights,
        }

    programs_meta = {}
    for name, fn, example_args, meta in build_programs(params):
        t0 = time.time()
        text, sig_args, sig_outs = to_hlo_text(fn, *example_args)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        programs_meta[name] = {
            "file": os.path.basename(path),
            "args": sig_args,
            "outs": sig_outs,
            **meta,
        }
        print(
            f"[aot] {name}: {len(text) / 1e3:.0f} kB, {len(sig_args)} args "
            f"({time.time() - t0:.1f}s)",
            flush=True,
        )

    grammar = corpus.Grammar()
    n_prompts = 48 if fast else common.PROMPTS_PER_DATASET
    datasets_meta = export_prompts(outdir, grammar, n_prompts)
    export_golden(outdir)

    manifest = {
        "version": 1,
        "batch": common.BATCH,
        "max_len": common.MAX_LEN,
        "vocab_size": common.VOCAB_SIZE,
        "pad_id": common.PAD_ID,
        "bos_id": common.BOS_ID,
        "eos_id": common.EOS_ID,
        "gammas": list(common.GAMMAS),
        "algos": list(common.ALGOS),
        "drafters": list(common.DRAFTERS),
        "models": models_meta,
        "programs": programs_meta,
        "datasets": datasets_meta,
        "built_unix": int(t_start),
        "fast_build": fast,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] bundle complete in {time.time() - t_start:.0f}s -> {outdir}", flush=True)


if __name__ == "__main__":
    main()
