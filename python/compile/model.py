"""L2: JAX decoder-only transformer LMs + the fused SpecDec iteration.

The serving contract (shared with rust/src/engine, enforced by the manifest):

* ``tokens`` is a (B, L) i32 ring of the full sequence; ``len`` is the
  current sequence length per row.  The *pending* token ``tokens[len-1]`` has
  not been fed through the models yet.
* KV caches hold rows for positions ``0..len-2`` plus stale junk above;
  every program consumes a contiguous run of positions starting at
  ``len-1`` and rewrites exactly those cache rows, so a query at position p
  only ever attends to rows that were written with the correct tokens
  (causal mask ``key_pos <= query_pos``).
* One SpecDec iteration (paper Algorithm 3) is ONE program:
  draft ``lax.scan`` (gamma steps) -> target parallel score (gamma+1
  positions, Pallas attention) -> L1 verify kernel -> token/len/done update.
  L3's hot loop is therefore a single PJRT ``execute`` per scheduler tick.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import common
from .kernels import attention as attn_kernel
from .kernels import verify as verify_kernel

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: common.ModelConfig, key) -> dict:
    """Initialise a parameter pytree (dict-of-dicts, deterministic order)."""
    keys = jax.random.split(key, 2 + cfg.n_layers)
    d, f = cfg.d_model, cfg.d_ff
    scale = d ** -0.5

    def dense(k, shape):
        return jax.random.normal(k, shape, jnp.float32) * (shape[0] ** -0.5)

    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, d), jnp.float32) * scale,
        "pos": jax.random.normal(keys[1], (cfg.max_len, d), jnp.float32) * 0.02,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + i], 6)
        params[f"layer_{i}"] = {
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "wq": dense(lk[0], (d, d)),
            "wk": dense(lk[1], (d, d)),
            "wv": dense(lk[2], (d, d)),
            "wo": dense(lk[3], (d, d)),
            "w1": dense(lk[4], (d, f)),
            "w2": dense(lk[5], (f, d)),
        }
    return params


def init_kv(cfg: common.ModelConfig, batch: int) -> dict:
    shape = (cfg.n_layers, batch, cfg.max_len, cfg.n_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, jnp.float32), "v": jnp.zeros(shape, jnp.float32)}


def _ln(x, p):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * p["g"] + p["b"]


def _update_rows(cache, new, start):
    """Per-row dynamic write: cache (B, L, H, D) <- new (B, T, H, D) at start (B,)."""

    def one(c, n, s):
        return jax.lax.dynamic_update_slice(c, n, (s, 0, 0))

    return jax.vmap(one)(cache, new, start)


def _jnp_attention(q, k, v, qpos):
    """Reference-path attention (used on the draft scan; the Pallas kernel
    covers the target scoring path)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    mask = kpos[None, None, None, :] <= qpos[:, None, :, None]
    logits = jnp.where(mask, logits, attn_kernel.NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", w, v)


def forward_block(cfg, params, kv, tokens_t, start_pos, *, use_pallas: bool):
    """Consume T tokens per row starting at per-row positions ``start_pos``.

    tokens_t: (B, T) i32; start_pos: (B,) i32.
    Returns probs (B, T, V) — probs[:, j] = M(. | ..., tokens_t[:, :j+1]) —
    and the updated kv cache.
    """
    b, t = tokens_t.shape
    h, hd = cfg.n_heads, cfg.head_dim
    pos = start_pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]  # (B, T)
    pos_c = jnp.minimum(pos, cfg.max_len - 1)
    x = params["embed"][tokens_t] + params["pos"][pos_c]
    new_kv = {"k": kv["k"], "v": kv["v"]}
    for i in range(cfg.n_layers):
        lp = params[f"layer_{i}"]
        y = _ln(x, lp["ln1"])
        q = (y @ lp["wq"]).reshape(b, t, h, hd)
        k = (y @ lp["wk"]).reshape(b, t, h, hd)
        v = (y @ lp["wv"]).reshape(b, t, h, hd)
        ck = _update_rows(new_kv["k"][i], k, start_pos)
        cv = _update_rows(new_kv["v"][i], v, start_pos)
        new_kv = {
            "k": new_kv["k"].at[i].set(ck),
            "v": new_kv["v"].at[i].set(cv),
        }
        if use_pallas:
            o = attn_kernel.cached_attention(q, ck, cv, pos, start_pos + t)
        else:
            o = _jnp_attention(q, ck, cv, pos)
        x = x + o.reshape(b, t, cfg.d_model) @ lp["wo"]
        y = _ln(x, lp["ln2"])
        x = x + jax.nn.gelu(y @ lp["w1"]) @ lp["w2"]
    x = _ln(x, params["ln_f"])
    logits = x @ params["embed"].T
    return jax.nn.softmax(logits, axis=-1), new_kv


# ---------------------------------------------------------------------------
# Training-path forward (dense, no cache) — used by train.py only.
# ---------------------------------------------------------------------------


def forward_train(cfg, params, tokens):
    """Full-sequence causal forward returning log-probs (B, T, V)."""
    b, t = tokens.shape
    h, hd = cfg.n_heads, cfg.head_dim
    pos = jnp.arange(t, dtype=jnp.int32)
    x = params["embed"][tokens] + params["pos"][pos][None]
    mask = jnp.tril(jnp.ones((t, t), bool))
    for i in range(cfg.n_layers):
        lp = params[f"layer_{i}"]
        y = _ln(x, lp["ln1"])
        q = (y @ lp["wq"]).reshape(b, t, h, hd)
        k = (y @ lp["wk"]).reshape(b, t, h, hd)
        v = (y @ lp["wv"]).reshape(b, t, h, hd)
        logits = jnp.einsum("bthd,bshd->bhts", q, k) * hd**-0.5
        logits = jnp.where(mask[None, None], logits, -1e30)
        w = jax.nn.softmax(logits, -1)
        o = jnp.einsum("bhts,bshd->bthd", w, v).reshape(b, t, cfg.d_model)
        x = x + o @ lp["wo"]
        y = _ln(x, lp["ln2"])
        x = x + jax.nn.gelu(y @ lp["w1"]) @ lp["w2"]
    x = _ln(x, params["ln_f"])
    return jax.nn.log_softmax(x @ params["embed"].T, axis=-1)


# ---------------------------------------------------------------------------
# Serving programs (the AOT export surface)
# ---------------------------------------------------------------------------


def _gather_pending(tokens, length):
    """tokens[b, length[b]-1] for each row."""

    def one(row, l):
        return jax.lax.dynamic_index_in_dim(row, l - 1, keepdims=False)

    return jax.vmap(one)(tokens, length)


def _sample_rows(probs, key):
    """Categorical sample per row via inverse CDF with explicit uniforms
    (keeps the sampling story identical across prefill/draft/baseline)."""
    u = jax.random.uniform(key, (probs.shape[0],))
    cdf = jnp.cumsum(probs, axis=-1)
    return jnp.sum(cdf <= u[:, None] * (1.0 - 1e-7), axis=-1).astype(jnp.int32)


def prefill(cfg, params, tokens, length):
    """Ingest prompts: writes KV rows 0..L-1 (rows >= len-1 are junk that the
    decode loop rewrites before reading — see module docstring)."""
    kv = init_kv(cfg, tokens.shape[0])
    _, kv = forward_block(
        cfg, params, kv, tokens, jnp.zeros_like(length), use_pallas=False
    )
    return kv


def draft_scan(cfg, params, kv, tokens, length, gamma, key):
    """gamma autoregressive draft steps from the pending token.

    Returns drafts (B, gamma) i32, qs (B, gamma, V), updated kv.
    qs[:, j] = M_s(. | c, X^j) and X_{j+1} ~ qs[:, j].
    """
    b = tokens.shape[0]
    cur = _gather_pending(tokens, length)  # X_0 = pending token

    def step(carry, j):
        kv_c, cur_t = carry
        probs, kv_n = forward_block(
            cfg, params, kv_c, cur_t[:, None], length - 1 + j, use_pallas=False
        )
        q_j = probs[:, 0]  # (B, V)
        nxt = _sample_rows(q_j, jax.random.fold_in(key, j))
        return (kv_n, nxt), (q_j, nxt)

    (kv, _), (qs, drafts) = jax.lax.scan(
        step, (kv, cur), jnp.arange(gamma, dtype=jnp.int32)
    )
    # scan stacks on axis 0 -> (gamma, B, ...); move batch first.
    return jnp.swapaxes(drafts, 0, 1), jnp.swapaxes(qs, 0, 1), kv


def target_score(cfg, params, kv, tokens, length, drafts, *, use_pallas=True):
    """Parallel scoring of the gamma+1 prefixes (Algorithm 3 line 3).

    Feeds [pending, X_1..X_gamma] at positions len-1..len+gamma-1; returns
    ps (B, gamma+1, V) with ps[:, i] = M_b(. | c, X^i), plus updated kv.
    """
    pending = _gather_pending(tokens, length)
    inp = jnp.concatenate([pending[:, None], drafts], axis=1)  # (B, gamma+1)
    ps, kv = forward_block(cfg, params, kv, inp, length - 1, use_pallas=use_pallas)
    return ps, kv


def _write_emitted(tokens, emitted, length):
    def one(row, em, l):
        return jax.lax.dynamic_update_slice(row, em, (l,))

    return jax.vmap(one)(tokens, emitted, length)


def spec_iter(
    cfg_t: common.ModelConfig,
    cfg_d: common.ModelConfig,
    params_t,
    params_d,
    tokens,
    length,
    kv_t,
    kv_d,
    seed,
    *,
    gamma: int,
    algo: str,
    max_len: int,
):
    """One fused SpecDec iteration (paper Algorithm 3 with VERIFY = `algo`).

    Returns (tokens', length', kv_t', kv_d', tau, emitted, done).
    """
    key = jax.random.PRNGKey(seed)
    k_draft, k_eta, k_res = jax.random.split(key, 3)
    b = tokens.shape[0]

    drafts, qs, kv_d = draft_scan(cfg_d, params_d, kv_d, tokens, length, gamma, k_draft)
    ps, kv_t = target_score(cfg_t, params_t, kv_t, tokens, length, drafts)

    etas = jax.random.uniform(k_eta, (b, gamma))
    us = jax.random.uniform(k_res, (b,))
    verifier = verify_kernel.VERIFIERS[algo]
    emitted, tau = verifier(ps, qs, drafts, etas, us, pad_id=common.PAD_ID)

    tokens = _write_emitted(tokens, emitted, length)
    new_len = length + tau + 1
    idx = jnp.arange(gamma + 1, dtype=jnp.int32)[None, :]
    eos_hit = jnp.any((emitted == common.EOS_ID) & (idx <= tau[:, None]), axis=1)
    out_of_room = new_len > max_len - (gamma + 2)
    done = (eos_hit | out_of_room).astype(jnp.int32)  # i32: PJRT-friendly
    return tokens, new_len, kv_t, kv_d, tau, emitted, done


def baseline_step(cfg, params, tokens, length, kv, seed, *, max_len: int):
    """One autoregressive target step — the paper's 1x wall-clock baseline."""
    key = jax.random.PRNGKey(seed)
    probs, kv = forward_block(
        cfg,
        params,
        kv,
        _gather_pending(tokens, length)[:, None],
        length - 1,
        use_pallas=False,
    )
    nxt = _sample_rows(probs[:, 0], key)
    tokens = _write_emitted(tokens, nxt[:, None], length)
    new_len = length + 1
    done = ((nxt == common.EOS_ID) | (new_len > max_len - 2)).astype(jnp.int32)
    return tokens, new_len, kv, nxt, done
